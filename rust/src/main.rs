//! `sgl` — the Sparse-Group Lasso solver CLI (Layer-3 entrypoint).
//!
//! Subcommands:
//!
//! - `solve`       one λ on a dataset (native solver, Algorithm 2)
//! - `path`        warm-started λ-path (§7.1)
//! - `cv`          (λ, τ)-grid validation (Fig. 3a protocol)
//! - `lambda-max`  critical parameter via Algorithm 1 (Eq. 22)
//! - `compare`     screening-rule timing comparison (Fig. 2c / 3b)
//! - `serve`       async solve service: submit a heterogeneous batch and
//!   stream completions (queue + result store + fingerprint cache +
//!   λ-sharded paths with dual-point handoff); `--fleet host:port,...`
//!   drains the shards into remote workers instead of solving in-process
//! - `worker`      remote solve worker: `sgl worker --listen host:port`
//!   serves the framed wire protocol (dataset shipping by fingerprint —
//!   monolithic or chunked, λ-shard solves with dual-point handoff,
//!   heartbeats, progress pings) until killed; `--register coord:port`
//!   announces it to a running coordinator so a restarted worker rejoins
//!   its fleet (`serve --register-addr` opens the matching listener)
//! - `xla`         solve through the AOT artifacts via PJRT (three-layer path)
//!
//! Datasets come from a config file (`--config run.toml`) or the built-in
//! synthetic/climate generators; `--dataset libsvm --libsvm-path f.svm`
//! loads svmlight text straight into the CSC backend (no dense detour).
//! `--design dense|csc` selects the design backend (CSC stores only the
//! nonzero entries, so epochs cost `O(nnz)`), `--algo cd|ista|fista` the
//! inner solver, and `--datafit quadratic|logistic|multitask` the loss
//! (logistic binarizes a real-valued target at its mean; multitask fits
//! `q = --tasks` response columns jointly — the synthetic loader plants
//! per-task coefficients, any other target is tiled across tasks, and
//! `q = 1` is bit-identical to the scalar quadratic run); all are also
//! available as `[dataset] design` / `[solver] algo` / `[solver]
//! datafit` / `[solver] tasks` TOML keys, and the service knobs as
//! `[service] workers/queue_depth/shards`.
//!
//! Observability: `--trace-out f.json` (or `SGL_TRACE=f.json`, or
//! `[trace] out`) records every solve as Chrome trace-event JSON —
//! open it in `about:tracing` / Perfetto; `--trace-sample k` thins the
//! per-gap-check instants to every k-th. `serve --metrics-addr host:port`
//! exposes the live metrics registry as a Prometheus text endpoint, and a
//! fleet run scrapes each remote worker's registry into it under a
//! `worker_<i>_` prefix before the final dump.

use anyhow::{bail, ensure, Context, Result};
use sgl::config::{
    parse_design_backend, parse_fleet_list, DatasetChoice, DesignBackend, RunConfig,
    UnknownBackendError,
};
use sgl::coordinator::jobs::{run_rule_comparison, RuleComparisonJob};
use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{run_worker_with, FleetConfig, RemoteFleet, WorkerOptions};
use sgl::coordinator::report::render_rule_timings;
use sgl::coordinator::service::{
    AnyProblem, JobId, QueueFullError, ServiceConfig, SolveRequest, SolveService,
};
use sgl::data::climate::{self, ClimateConfig};
use sgl::data::synthetic::{self, SyntheticConfig};
use sgl::data::{csvio, libsvm, Dataset, SparseDataset};
use sgl::linalg::{CscMatrix, Design};
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::cv::{
    split_rows, validate_tau_grid, validate_tau_grid_logistic, validate_tau_grid_multitask,
};
use sgl::solver::datafit::{Datafit, FitKind, Logistic, MultiTaskQuadratic};
use sgl::solver::groups::Groups;
use sgl::solver::path::{solve_path_with, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::sweep::SweepMode;
use sgl::solver::SolverKind;
use sgl::util::cli::{Args, OptSpec};
use sgl::util::trace;
use std::collections::HashMap;
use std::sync::Arc;

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", help: "TOML config file", takes_value: true, default: None },
        OptSpec { name: "dataset", help: "synthetic|climate|libsvm", takes_value: true, default: Some("synthetic") },
        OptSpec { name: "libsvm-path", help: "libsvm/svmlight file for --dataset libsvm", takes_value: true, default: None },
        OptSpec { name: "group-size", help: "uniform group size for libsvm datasets", takes_value: true, default: None },
        OptSpec { name: "design", help: "dense|csc design backend", takes_value: true, default: None },
        OptSpec { name: "algo", help: "cd|ista|fista inner solver", takes_value: true, default: None },
        OptSpec { name: "datafit", help: "quadratic|logistic|multitask loss", takes_value: true, default: None },
        OptSpec { name: "tasks", help: "response columns q for --datafit multitask", takes_value: true, default: None },
        OptSpec { name: "tau", help: "l1/group mixing in [0,1]", takes_value: true, default: None },
        OptSpec { name: "lambda-frac", help: "lambda as a fraction of lambda_max", takes_value: true, default: Some("0.1") },
        OptSpec { name: "tol", help: "target duality gap", takes_value: true, default: None },
        OptSpec { name: "rule", help: "none|static|dynamic|dst3|gap_safe|gap_safe_seq", takes_value: true, default: None },
        OptSpec { name: "sweep", help: "serial|parallel intra-solve epoch mode", takes_value: true, default: None },
        OptSpec { name: "sweep-threads", help: "threads per parallel sweep (0 = auto)", takes_value: true, default: None },
        OptSpec { name: "kernels", help: "auto|scalar|simd kernel policy", takes_value: true, default: None },
        OptSpec { name: "delta", help: "path grid exponent", takes_value: true, default: None },
        OptSpec { name: "t-count", help: "path grid size", takes_value: true, default: None },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: None },
        OptSpec { name: "threads", help: "worker threads (0 = auto)", takes_value: true, default: None },
        OptSpec { name: "workers", help: "serve: worker threads (0 = auto)", takes_value: true, default: None },
        OptSpec { name: "queue-depth", help: "serve: max queued jobs", takes_value: true, default: None },
        OptSpec { name: "shards", help: "serve: lambda-range shards per path", takes_value: true, default: None },
        OptSpec { name: "fleet", help: "serve: remote workers host:port,host:port", takes_value: true, default: None },
        OptSpec { name: "fleet-conns", help: "serve: connections per fleet worker", takes_value: true, default: None },
        OptSpec { name: "fleet-chunk-mb", help: "serve: chunked-ship threshold in MiB", takes_value: true, default: None },
        OptSpec { name: "progress-deadline-ms", help: "serve: max ms between fleet frames (0 = off)", takes_value: true, default: None },
        OptSpec { name: "rejoin-grace-ms", help: "serve: ms to wait for a worker rejoin when the fleet is dead (0 = off)", takes_value: true, default: None },
        OptSpec { name: "register-addr", help: "serve: worker registration listener host:port", takes_value: true, default: None },
        OptSpec { name: "listen", help: "worker: bind address (port 0 = auto)", takes_value: true, default: Some("127.0.0.1:7171") },
        OptSpec { name: "register", help: "worker: announce to this coordinator registration address", takes_value: true, default: None },
        OptSpec { name: "store-capacity", help: "worker: datasets retained before LRU eviction", takes_value: true, default: None },
        OptSpec { name: "progress-ms", help: "worker: progress-ping interval during solves (0 = off)", takes_value: true, default: None },
        OptSpec { name: "trace-out", help: "write a Chrome trace-event JSON of the run (also SGL_TRACE)", takes_value: true, default: None },
        OptSpec { name: "trace-sample", help: "record every k-th gap-check event (default 1 = all)", takes_value: true, default: None },
        OptSpec { name: "metrics-addr", help: "serve: Prometheus text endpoint host:port", takes_value: true, default: None },
        OptSpec { name: "scale", help: "small|paper dataset scale", takes_value: true, default: Some("small") },
        OptSpec { name: "out", help: "output CSV path", takes_value: true, default: None },
        OptSpec { name: "artifacts", help: "artifacts dir for `xla`", takes_value: true, default: Some("artifacts") },
    ]
}

fn main() {
    let args = Args::parse_or_exit(&specs());
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        if let Some(ub) = e.downcast_ref::<UnknownBackendError>() {
            eprintln!(
                "hint: {:?} is not a design backend; valid choices are: dense, csc",
                ub.given
            );
        }
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(&path))?,
        None => RunConfig::default(),
    };
    // CLI overrides.
    if let Some(v) = args.get("design") {
        cfg.design = parse_design_backend(&v).context("--design")?;
    }
    if let Some(v) = args.get("algo") {
        cfg.algo = SolverKind::from_name(&v)
            .with_context(|| format!("unknown --algo {v} (cd|ista|fista)"))?;
    }
    if let Some(v) = args.get("datafit") {
        cfg.datafit = FitKind::from_name(&v)
            .with_context(|| format!("unknown --datafit {v} (quadratic|logistic|multitask)"))?;
    }
    if let Some(v) = args.get("tasks") {
        cfg.tasks = v.parse().context("--tasks")?;
    }
    if let Some(v) = args.get("tau") {
        cfg.tau = v.parse().context("--tau")?;
    }
    if let Some(v) = args.get("tol") {
        cfg.tol = v.parse().context("--tol")?;
    }
    if let Some(v) = args.get("rule") {
        cfg.rule = RuleKind::from_name(&v).with_context(|| format!("unknown rule {v}"))?;
    }
    if let Some(v) = args.get("sweep") {
        cfg.sweep = SweepMode::from_name(&v)
            .with_context(|| format!("unknown sweep mode {v} (serial|parallel)"))?;
    }
    if let Some(v) = args.get("sweep-threads") {
        cfg.sweep_threads = v.parse().context("--sweep-threads")?;
    }
    if let Some(v) = args.get("kernels") {
        cfg.kernels = sgl::linalg::KernelPolicy::from_name(&v)
            .with_context(|| format!("unknown kernel policy {v} (auto|scalar|simd)"))?;
    }
    if let Some(v) = args.get("delta") {
        cfg.delta = v.parse().context("--delta")?;
    }
    if let Some(v) = args.get("t-count") {
        cfg.t_count = v.parse().context("--t-count")?;
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    if let Some(v) = args.get("threads") {
        cfg.threads = v.parse().context("--threads")?;
    }
    if let Some(v) = args.get("workers") {
        cfg.service_workers = v.parse().context("--workers")?;
    }
    if let Some(v) = args.get("queue-depth") {
        cfg.service_queue_depth = v.parse().context("--queue-depth")?;
    }
    if let Some(v) = args.get("shards") {
        cfg.service_shards = v.parse().context("--shards")?;
    }
    if let Some(v) = args.get("fleet") {
        cfg.service_fleet = parse_fleet_list(&v).context("--fleet")?;
    }
    if let Some(v) = args.get("fleet-conns") {
        cfg.service_fleet_conns = v.parse().context("--fleet-conns")?;
    }
    if let Some(v) = args.get("fleet-chunk-mb") {
        cfg.service_fleet_chunk_mb = v.parse().context("--fleet-chunk-mb")?;
    }
    if let Some(v) = args.get("progress-deadline-ms") {
        cfg.service_progress_deadline_ms = v.parse().context("--progress-deadline-ms")?;
    }
    if let Some(v) = args.get("rejoin-grace-ms") {
        cfg.service_rejoin_grace_ms = v.parse().context("--rejoin-grace-ms")?;
    }
    if let Some(v) = args.get("register-addr") {
        cfg.service_register_addr = Some(v);
    }
    if let Some(v) = args.get("trace-out") {
        cfg.trace_out = Some(v);
    }
    if let Some(v) = args.get("trace-sample") {
        cfg.trace_sample = v.parse().context("--trace-sample")?;
    }
    if let Some(v) = args.get("metrics-addr") {
        cfg.metrics_addr = Some(v);
    }
    // `SGL_TRACE=path` turns tracing on without touching flags or config
    // (lowest precedence: an explicit --trace-out / [trace] out wins).
    if cfg.trace_out.is_none() {
        if let Ok(v) = std::env::var("SGL_TRACE") {
            if !v.is_empty() {
                cfg.trace_out = Some(v);
            }
        }
    }
    if args.get("config").is_none() {
        cfg.dataset = match args.get_or("dataset", "synthetic").as_str() {
            "synthetic" => DatasetChoice::Synthetic,
            "climate" => DatasetChoice::Climate,
            "libsvm" => {
                // Sparse loaders default to the CSC backend; an explicit
                // --design still wins (it was applied above).
                if args.get("design").is_none() {
                    cfg.design = DesignBackend::Csc;
                }
                DatasetChoice::Libsvm {
                    path: args
                        .get("libsvm-path")
                        .context("--dataset libsvm requires --libsvm-path")?,
                    group_size: match args.get("group-size") {
                        Some(v) => v.parse().context("--group-size")?,
                        None => 1,
                    },
                }
            }
            other => bail!("unknown dataset {other} (use a config file for csv)"),
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

/// What a loader produced: a dense dataset or a CSC one (libsvm). The
/// backend the solve runs on is still `cfg.design` — `with_backend!`
/// converts only when the two disagree, so libsvm → CSC never touches a
/// dense matrix.
enum LoadedData {
    Dense(Dataset),
    Sparse(SparseDataset),
}

fn build_data(cfg: &RunConfig, scale: &str) -> Result<LoadedData> {
    Ok(match &cfg.dataset {
        DatasetChoice::Libsvm { path, group_size } => LoadedData::Sparse(
            libsvm::read_libsvm(std::path::Path::new(path), *group_size)?,
        ),
        _ => LoadedData::Dense(build_dataset(cfg, scale)?),
    })
}

fn build_dataset(cfg: &RunConfig, scale: &str) -> Result<Dataset> {
    Ok(match &cfg.dataset {
        DatasetChoice::Synthetic => {
            let sc = if scale == "paper" {
                SyntheticConfig {
                    n: cfg.synth_n,
                    n_groups: cfg.synth_groups,
                    group_size: cfg.synth_group_size,
                    rho: cfg.synth_rho,
                    gamma1: cfg.synth_gamma1,
                    gamma2: cfg.synth_gamma2,
                    seed: cfg.seed,
                    ..Default::default()
                }
            } else {
                SyntheticConfig::small(cfg.seed)
            };
            if cfg.datafit == FitKind::MultiTask {
                // Multi-response loader path: one shared X, per-task
                // planted coefficients, task-major y of length n·q.
                synthetic::generate_multitask(&sc, cfg.tasks).dataset
            } else {
                synthetic::generate(&sc).dataset
            }
        }
        DatasetChoice::Climate => {
            let cc = if scale == "paper" {
                ClimateConfig {
                    grid_lon: cfg.climate_lon,
                    grid_lat: cfg.climate_lat,
                    n_months: cfg.climate_months,
                    seed: cfg.seed,
                    ..Default::default()
                }
            } else {
                ClimateConfig::small(cfg.seed)
            };
            let mut data = climate::generate(&cc);
            climate::preprocess(&mut data);
            data.dataset
        }
        DatasetChoice::Csv { x_path, y_path, group_size } => {
            let x = csvio::read_matrix_csv(std::path::Path::new(x_path))?;
            let y = csvio::read_vector(std::path::Path::new(y_path))?;
            anyhow::ensure!(x.n_cols() % group_size == 0, "p not divisible by group size");
            let groups = Groups::uniform(x.n_cols() / group_size, *group_size);
            Dataset { name: format!("csv({x_path})"), x, y, groups }
        }
        DatasetChoice::Libsvm { .. } => {
            bail!("libsvm datasets are sparse-loaded; route through build_data")
        }
    })
}

/// The configured solver options (every subcommand routes through this,
/// so `--sweep`/`--sweep-threads` reach each inner solve).
fn solve_opts(cfg: &RunConfig, record_history: bool) -> SolveOptions {
    SolveOptions {
        tol: cfg.tol,
        fce: cfg.fce,
        max_epochs: cfg.max_epochs,
        rule: cfg.rule,
        record_history,
        sweep: cfg.sweep,
        sweep_threads: cfg.sweep_threads,
        tuning: cfg.sweep_tuning(),
    }
}

/// Binary labels for the logistic datafit: a target already in `{0, 1}`
/// passes through unchanged, a real-valued one is thresholded at its
/// mean (deterministic, so reruns see the same classification problem).
fn logistic_labels(y: &[f64]) -> Vec<f64> {
    if y.iter().all(|&v| v == 0.0 || v == 1.0) {
        return y.to_vec();
    }
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    y.iter().map(|&v| f64::from(v > mean)).collect()
}

/// A sparse-group logistic problem on any backend from a loaded target.
fn logistic_problem<D: Design>(
    x: D,
    y: Vec<f64>,
    groups: Groups,
    tau: f64,
) -> SglProblem<D, Logistic> {
    let weights = groups.sqrt_size_weights();
    SglProblem::with_datafit(x, logistic_labels(&y), groups, tau, weights, Logistic)
}

/// A task-major multi-response target. The synthetic loader already
/// produces `n · tasks` entries; any scalar target (climate, csv,
/// libsvm) is tiled across tasks so every dataset kind stays runnable
/// under `--datafit multitask`. Both branches are the identity at q = 1.
fn multitask_target(y: Vec<f64>, n: usize, tasks: usize) -> Vec<f64> {
    if y.len() == n * tasks {
        return y;
    }
    assert_eq!(y.len(), n, "target must hold n or n * tasks entries");
    let mut out = Vec::with_capacity(n * tasks);
    for _ in 0..tasks {
        out.extend_from_slice(&y);
    }
    out
}

/// A sparse-group multi-task problem on any backend.
fn multitask_problem<D: Design>(
    x: D,
    y: Vec<f64>,
    groups: Groups,
    tau: f64,
    tasks: usize,
) -> SglProblem<D, MultiTaskQuadratic> {
    let n = x.n_rows();
    let weights = groups.sqrt_size_weights();
    SglProblem::with_datafit(
        x,
        multitask_target(y, n, tasks),
        groups,
        tau,
        weights,
        MultiTaskQuadratic::new(tasks),
    )
}

/// `solve` on any backend and datafit.
fn cmd_solve<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    cfg: &RunConfig,
    args: &Args,
    name: &str,
) {
    let lambda = args.get_f64("lambda-frac", 0.1) * pb.lambda_max();
    let opts = solve_opts(cfg, true);
    let res = match cfg.algo {
        SolverKind::Cd => sgl::solver::cd::solve(pb, lambda, None, &opts),
        SolverKind::Ista => sgl::solver::ista::solve_ista(pb, lambda, None, &opts),
        SolverKind::Fista => sgl::solver::fista::solve_fista(pb, lambda, None, &opts),
    };
    // ‖y‖² for least squares, n·ln2 for logistic — the same normalizer
    // the solvers use for their relative stopping rule.
    let y2: f64 = pb.datafit.gap_scale(&pb.y);
    println!(
        "dataset={} design={} datafit={} algo={} n={} p={} nnz={} lambda={lambda:.5e}",
        name,
        cfg.design.name(),
        pb.datafit.kind().name(),
        cfg.algo.name(),
        pb.n(),
        pb.p(),
        pb.x.nnz()
    );
    println!(
        "converged={} gap={:.3e} (rel {:.2e}) epochs={} time={:.3}s \
         active_features={} active_groups={}",
        res.converged,
        res.gap,
        res.gap / y2,
        res.epochs,
        res.elapsed_s,
        res.active.n_active_features(),
        res.active.n_active_groups()
    );
}

/// `path` on any backend and datafit.
fn cmd_path<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    cfg: &RunConfig,
    args: &Args,
) -> Result<()> {
    let opts = PathOptions {
        delta: cfg.delta,
        t_count: cfg.t_count,
        solve: solve_opts(cfg, false),
    };
    let lambdas = lambda_grid(pb.lambda_max(), opts.delta, opts.t_count);
    let path = solve_path_with(pb, &lambdas, &opts, cfg.algo);
    println!(
        "path: {} lambdas, design={}, datafit={}, algo={}, rule={}, total {:.3}s, \
         epochs={}, all converged={}",
        path.lambdas.len(),
        cfg.design.name(),
        pb.datafit.kind().name(),
        cfg.algo.name(),
        cfg.rule.name(),
        path.total_s,
        path.total_epochs(),
        path.all_converged()
    );
    if let Some(out) = args.get("out") {
        let rows: Vec<Vec<f64>> = path
            .lambdas
            .iter()
            .zip(&path.results)
            .map(|(l, r)| {
                vec![
                    *l,
                    r.gap,
                    r.epochs as f64,
                    r.active.n_active_features() as f64,
                    r.active.n_active_groups() as f64,
                ]
            })
            .collect();
        csvio::write_csv(
            std::path::Path::new(&out),
            &["lambda", "gap", "epochs", "active_features", "active_groups"],
            &rows,
        )?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `serve`: spin up the async solve service, submit a heterogeneous batch
/// (mixed rule × tolerance × solver × backend, one λ-sharded path, one
/// duplicate to exercise the fingerprint cache) and stream completions as
/// they land, then dump the service metrics.
fn cmd_serve(data: LoadedData, cfg: &RunConfig) -> Result<()> {
    // A dense-loaded dataset serves both backends side by side; a
    // sparse-loaded one (libsvm) stays CSC end to end unless the user
    // explicitly asked for the dense backend (same contract as
    // `with_backend!`), in which case dense jobs join the batch too.
    // Each backend also gets a logistic twin (labels binarized at the
    // target's mean) and a multi-task twin, so the batch mixes all three
    // datafits freely.
    type LogDense = Arc<SglProblem<sgl::linalg::Matrix, Logistic>>;
    type LogCsc = Arc<SglProblem<CscMatrix, Logistic>>;
    type MtDense = Arc<SglProblem<sgl::linalg::Matrix, MultiTaskQuadratic>>;
    type MtCsc = Arc<SglProblem<CscMatrix, MultiTaskQuadratic>>;
    // The batch always demos a genuinely multi-column response: q from
    // --tasks when configured, 2 otherwise (scalar targets are tiled).
    let mt_q = cfg.tasks.max(2);
    let (dense_pb, csc_pb, dense_log, csc_log, dense_mt, csc_mt): (
        Option<Arc<SglProblem>>,
        Arc<SglProblem<CscMatrix>>,
        Option<LogDense>,
        LogCsc,
        Option<MtDense>,
        MtCsc,
    ) = match data {
        LoadedData::Dense(d) => {
            let csc = CscMatrix::from_dense(&d.x);
            // Task 0 is the scalar target (a multitask synthetic load
            // carries n·q entries task-major; every other load exactly n).
            let y1 = d.y[..d.x.n_rows()].to_vec();
            (
                Some(Arc::new(SglProblem::new(
                    d.x.clone(),
                    y1.clone(),
                    d.groups.clone(),
                    cfg.tau,
                ))),
                Arc::new(SglProblem::new(csc.clone(), y1.clone(), d.groups.clone(), cfg.tau)),
                Some(Arc::new(logistic_problem(
                    d.x.clone(),
                    y1.clone(),
                    d.groups.clone(),
                    cfg.tau,
                ))),
                Arc::new(logistic_problem(csc.clone(), y1, d.groups.clone(), cfg.tau)),
                Some(Arc::new(multitask_problem(
                    d.x,
                    d.y.clone(),
                    d.groups.clone(),
                    cfg.tau,
                    mt_q,
                ))),
                Arc::new(multitask_problem(csc, d.y, d.groups, cfg.tau, mt_q)),
            )
        }
        LoadedData::Sparse(s) => {
            let (dense, dense_log, dense_mt) = match cfg.design {
                DesignBackend::Dense => {
                    let x = s.x.to_dense();
                    (
                        Some(Arc::new(SglProblem::new(
                            x.clone(),
                            s.y.clone(),
                            s.groups.clone(),
                            cfg.tau,
                        ))),
                        Some(Arc::new(logistic_problem(
                            x.clone(),
                            s.y.clone(),
                            s.groups.clone(),
                            cfg.tau,
                        ))),
                        Some(Arc::new(multitask_problem(
                            x,
                            s.y.clone(),
                            s.groups.clone(),
                            cfg.tau,
                            mt_q,
                        ))),
                    )
                }
                DesignBackend::Csc => (None, None, None),
            };
            (
                dense,
                Arc::new(SglProblem::new(s.x.clone(), s.y.clone(), s.groups.clone(), cfg.tau)),
                dense_log,
                Arc::new(logistic_problem(s.x.clone(), s.y.clone(), s.groups.clone(), cfg.tau)),
                dense_mt,
                Arc::new(multitask_problem(s.x, s.y, s.groups, cfg.tau, mt_q)),
            )
        }
    };
    let metrics = Arc::new(Metrics::new());
    if let Some(addr) = &cfg.metrics_addr {
        let local = spawn_metrics_endpoint(addr, metrics.clone())?;
        println!("metrics endpoint: http://{local}/metrics");
    }
    let svc_cfg = ServiceConfig {
        workers: cfg.service_workers,
        queue_depth: cfg.service_queue_depth,
        result_capacity: cfg.service_result_capacity,
        cache_capacity: cfg.service_cache_capacity,
    };
    // With a fleet configured, shards leave the process: the "workers"
    // become dispatch threads blocked on remote exchanges.
    let fleet = if cfg.service_fleet.is_empty() {
        None
    } else {
        let f = Arc::new(RemoteFleet::connect(
            &cfg.service_fleet,
            FleetConfig {
                conns_per_worker: cfg.service_fleet_conns,
                ship_chunk_bytes: cfg.service_fleet_chunk_mb << 20,
                progress_deadline: std::time::Duration::from_millis(
                    cfg.service_progress_deadline_ms,
                ),
                rejoin_grace: std::time::Duration::from_millis(cfg.service_rejoin_grace_ms),
            },
            metrics.clone(),
        )?);
        if let Some(addr) = &cfg.service_register_addr {
            let local = f.serve_registrations(addr)?;
            println!("fleet registration listener: {local}");
        }
        Some(f)
    };
    let svc = match &fleet {
        None => SolveService::with_metrics(svc_cfg, metrics.clone()),
        Some(f) => SolveService::with_fleet(svc_cfg, metrics.clone(), f.clone()),
    };
    match &fleet {
        None => println!(
            "service up: {} workers, queue depth {}, n={}, p={}",
            svc.workers(),
            cfg.service_queue_depth,
            csc_pb.n(),
            csc_pb.p()
        ),
        Some(f) => println!(
            "service up: fleet of {} remote workers ({}), capacity {}, queue depth {}, \
             n={}, p={}",
            f.workers_alive(),
            f.addrs().join(","),
            f.capacity(),
            cfg.service_queue_depth,
            csc_pb.n(),
            csc_pb.p()
        ),
    }

    let make = |pb: AnyProblem, rule: RuleKind, tol: f64, solver: SolverKind, shards: usize| {
        SolveRequest {
            solver,
            shards,
            label: format!(
                "{}{}/{}/{}@{tol:.0e}{}",
                pb.backend_name(),
                match pb.datafit_kind() {
                    FitKind::Quadratic => String::new(),
                    FitKind::Logistic => "+logistic".into(),
                    FitKind::MultiTask => format!("+mt{}", pb.tasks()),
                },
                solver.name(),
                rule.name(),
                if shards > 1 { format!("/k{shards}") } else { String::new() }
            ),
            ..SolveRequest::new(
                pb,
                PathOptions {
                    delta: cfg.delta,
                    t_count: cfg.t_count,
                    solve: SolveOptions { tol, rule, ..solve_opts(cfg, false) },
                },
            )
        }
    };

    // Heterogeneous batch: rules × tolerances × solvers × backends.
    let mut batch: Vec<SolveRequest> = Vec::new();
    for rule in [RuleKind::GapSafe, RuleKind::GapSafeSeq] {
        for tol in [1e-4, 1e-6] {
            for solver in [SolverKind::Cd, SolverKind::Fista] {
                batch.push(make(AnyProblem::Csc(csc_pb.clone()), rule, tol, solver, 1));
                if let Some(dp) = &dense_pb {
                    batch.push(make(AnyProblem::Dense(dp.clone()), rule, tol, solver, 1));
                }
            }
        }
    }
    // Classification rides the same queue: logistic paths under the GAP
    // rules, mixed freely with the quadratic traffic above.
    for solver in [SolverKind::Cd, SolverKind::Fista] {
        batch.push(make(
            AnyProblem::CscLogistic(csc_log.clone()),
            RuleKind::GapSafeSeq,
            1e-6,
            solver,
            1,
        ));
    }
    if let Some(dl) = &dense_log {
        batch.push(make(
            AnyProblem::DenseLogistic(dl.clone()),
            RuleKind::GapSafe,
            1e-6,
            SolverKind::Cd,
            1,
        ));
    }
    // Multi-response paths join the same queue — the multi-task dual
    // geometry is quadratic, so the least-squares spheres are admissible.
    for solver in [SolverKind::Cd, SolverKind::Fista] {
        batch.push(make(
            AnyProblem::CscMultiTask(csc_mt.clone()),
            RuleKind::GapSafe,
            1e-6,
            solver,
            1,
        ));
    }
    if let Some(dm) = &dense_mt {
        batch.push(make(
            AnyProblem::DenseMultiTask(dm.clone()),
            RuleKind::Dst3,
            1e-6,
            SolverKind::Cd,
            1,
        ));
    }
    // One λ-sharded path per datafit: the dual-point handoff pipeline.
    if cfg.service_shards > 1 {
        batch.push(make(
            AnyProblem::Csc(csc_pb.clone()),
            RuleKind::GapSafeSeq,
            cfg.tol,
            SolverKind::Cd,
            cfg.service_shards,
        ));
        batch.push(make(
            AnyProblem::CscLogistic(csc_log.clone()),
            RuleKind::GapSafeSeq,
            cfg.tol,
            SolverKind::Cd,
            cfg.service_shards,
        ));
        batch.push(make(
            AnyProblem::CscMultiTask(csc_mt.clone()),
            RuleKind::GapSafeSeq,
            cfg.tol,
            SolverKind::Cd,
            cfg.service_shards,
        ));
    }
    // A duplicate of the first request: once its twin completes, this is
    // answered from the fingerprint cache without re-solving.
    let dup = batch[0].clone();

    let mut labels: HashMap<JobId, String> = HashMap::new();
    for req in batch {
        let id = submit_draining(&svc, &mut labels, req)?;
        println!("submitted {id}: {}", labels[&id]);
    }
    // Stream completions in the order they land.
    stream_completions(&svc, &mut labels);

    let mut dup = dup;
    dup.label = format!("{} (duplicate)", dup.label);
    let dup_id = submit_draining(&svc, &mut labels, dup)?;
    stream_completions(&svc, &mut labels);
    println!(
        "cache hits: {} (duplicate {} served without re-solving: {})",
        metrics.counter("service_cache_hits"),
        dup_id,
        svc.was_cached(dup_id),
    );
    if let Some(f) = &fleet {
        // Pull each worker's metrics registry into ours (prefixed
        // `worker_<i>_`) before the final dump, then report liveness with
        // the summary the Pong now carries.
        let scraped = f.scrape(std::time::Duration::from_secs(5));
        for (addr, state) in f.heartbeat(std::time::Duration::from_secs(5)) {
            match state.summary() {
                Some(s) => println!(
                    "fleet worker {addr}: alive, {} solves, {} in flight, up {}s",
                    s.solves, s.in_flight, s.uptime_ticks
                ),
                None if state.is_alive() => println!("fleet worker {addr}: alive (busy)"),
                None => println!("fleet worker {addr}: dead"),
            }
        }
        println!("scraped {scraped} worker registries into the service metrics");
    }
    println!("\nservice metrics:\n{}", metrics.render_text());
    Ok(())
}

/// Serve the coordinator's metrics registry as Prometheus text exposition
/// over plain HTTP: one listener thread, one `GET` per connection, the
/// same `render_text` the final dump prints. Returns the bound address
/// (`--metrics-addr host:0` picks a free port).
fn spawn_metrics_endpoint(addr: &str, metrics: Arc<Metrics>) -> Result<std::net::SocketAddr> {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding metrics endpoint {addr}"))?;
    let local = listener.local_addr()?;
    std::thread::Builder::new().name("sgl-metrics".into()).spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            // Drain (a prefix of) the request and answer every path the
            // same way — scrapers only ever GET.
            let mut buf = [0u8; 1024];
            let _ = stream.read(&mut buf);
            let body = metrics.render_text();
            let reply = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
                body.len()
            );
            let _ = stream.write_all(reply.as_bytes());
        }
    })?;
    Ok(local)
}

/// Submit with backpressure: a full queue ([`QueueFullError`]) drains one
/// completion (printing it) and retries instead of aborting the demo.
fn submit_draining(
    svc: &SolveService,
    labels: &mut HashMap<JobId, String>,
    req: SolveRequest,
) -> Result<JobId> {
    let label = req.label.clone();
    loop {
        match svc.submit(req.clone()) {
            Ok(id) => {
                labels.insert(id, label);
                return Ok(id);
            }
            Err(e) if e.is::<QueueFullError>() => match svc.wait_next() {
                Some(done) => print_completion(svc, labels, done),
                None => std::thread::sleep(std::time::Duration::from_millis(20)),
            },
            Err(e) => return Err(e).with_context(|| format!("submitting {label}")),
        }
    }
}

/// Print each completed job as [`SolveService::wait_next`] yields it.
fn stream_completions(svc: &SolveService, labels: &mut HashMap<JobId, String>) {
    while let Some(id) = svc.wait_next() {
        print_completion(svc, labels, id);
    }
}

fn print_completion(svc: &SolveService, labels: &mut HashMap<JobId, String>, id: JobId) {
    let label = labels.remove(&id).unwrap_or_else(|| "?".into());
    match svc.result(id) {
        Some(r) => println!(
            "completed {id} {label}: {} lambdas, {:.3}s solve, {} epochs, converged={}{}",
            r.lambdas.len(),
            r.total_s,
            r.total_epochs(),
            r.all_converged(),
            if svc.was_cached(id) { " [cache]" } else { "" }
        ),
        None => println!("finished {id} {label}: {:?}", svc.poll(id)),
    }
}

/// `compare` on any backend.
fn cmd_compare<D: Design>(pb: SglProblem<D>, cfg: &RunConfig, threads: usize) {
    let job = RuleComparisonJob {
        tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
        delta: cfg.delta,
        t_count: cfg.t_count,
        fce: cfg.fce,
        max_epochs: cfg.max_epochs,
        serial_timing: true,
        ..Default::default()
    };
    let timings = run_rule_comparison(std::sync::Arc::new(pb), &job, threads, None);
    println!("{}", render_rule_timings(&timings));
}

/// Bind `$x`/`$y`/`$groups` to the configured backend's design and run
/// `$body` — the one place the (loader output × backend choice) product
/// is expanded, so every subcommand stays backend- and loader-complete by
/// construction. A CSC-loaded dataset on the CSC backend passes through
/// untouched (no dense detour); conversion happens only when the two
/// disagree. (`$body` is monomorphized once per backend through the
/// generic `cmd_*` helpers.)
macro_rules! with_backend {
    ($cfg:expr, $data:expr, |$x:ident, $y:ident, $groups:ident| $body:expr) => {{
        match ($cfg.design, $data) {
            (DesignBackend::Dense, LoadedData::Dense(d)) => {
                let ($x, $y, $groups) = (d.x, d.y, d.groups);
                $body
            }
            (DesignBackend::Csc, LoadedData::Dense(d)) => {
                let $x = CscMatrix::from_dense(&d.x);
                let ($y, $groups) = (d.y, d.groups);
                $body
            }
            (DesignBackend::Csc, LoadedData::Sparse(s)) => {
                let ($x, $y, $groups) = (s.x, s.y, s.groups);
                $body
            }
            (DesignBackend::Dense, LoadedData::Sparse(s)) => {
                // Explicitly requested dense on a sparse-loaded dataset.
                let $x = s.x.to_dense();
                let ($y, $groups) = (s.y, s.groups);
                $body
            }
        }
    }};
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    let cfg = load_config(args)?;
    // Kernel policy is process-global (like SGL_THREADS): one store up
    // front covers every backend and worker thread in this process.
    sgl::linalg::simd::set_policy(cfg.kernels);
    // Tracing likewise: the collector is process-global, so enabling it
    // here covers every solver thread a subcommand spins up. When it is
    // off (the default), the instrumented sites are a single relaxed
    // atomic load and solver output is bit-identical.
    if cfg.trace_out.is_some() {
        trace::enable(cfg.trace_sample);
    }
    let scale = args.get_or("scale", "small");
    let threads = cfg.effective_threads();

    match cmd {
        "solve" => {
            let data = build_data(&cfg, &scale)?;
            let name = data_name(&cfg);
            with_backend!(cfg, data, |x, y, groups| {
                match cfg.datafit {
                    FitKind::Quadratic => {
                        let pb = SglProblem::new(x, y, groups, cfg.tau);
                        cmd_solve(&pb, &cfg, args, name)
                    }
                    FitKind::Logistic => {
                        let pb = logistic_problem(x, y, groups, cfg.tau);
                        cmd_solve(&pb, &cfg, args, name)
                    }
                    FitKind::MultiTask => {
                        let pb = multitask_problem(x, y, groups, cfg.tau, cfg.tasks);
                        cmd_solve(&pb, &cfg, args, name)
                    }
                }
            });
        }
        "path" => {
            let data = build_data(&cfg, &scale)?;
            with_backend!(cfg, data, |x, y, groups| {
                match cfg.datafit {
                    FitKind::Quadratic => {
                        let pb = SglProblem::new(x, y, groups, cfg.tau);
                        cmd_path(&pb, &cfg, args)?
                    }
                    FitKind::Logistic => {
                        let pb = logistic_problem(x, y, groups, cfg.tau);
                        cmd_path(&pb, &cfg, args)?
                    }
                    FitKind::MultiTask => {
                        let pb = multitask_problem(x, y, groups, cfg.tau, cfg.tasks);
                        cmd_path(&pb, &cfg, args)?
                    }
                }
            });
        }
        "cv" => {
            let data = build_data(&cfg, &scale)?;
            let taus: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
            let opts = PathOptions {
                delta: cfg.delta,
                t_count: cfg.t_count,
                solve: SolveOptions {
                    tol: cfg.tol,
                    record_history: false,
                    sweep: cfg.sweep,
                    sweep_threads: cfg.sweep_threads,
                    tuning: cfg.sweep_tuning(),
                    ..Default::default()
                },
            };
            match cfg.datafit {
                FitKind::Quadratic => {
                    let cv = with_backend!(cfg, data, |x, y, groups| {
                        let split = split_rows(x.n_rows(), 0.5, cfg.seed);
                        validate_tau_grid(&x, &y, &groups, &taus, &opts, &split, threads)
                    });
                    println!(
                        "best tau={} lambda={:.4e} test mse={:.5e}",
                        cv.best_tau, cv.best_lambda, cv.best_mse
                    );
                }
                FitKind::Logistic => {
                    let cv = with_backend!(cfg, data, |x, y, groups| {
                        let split = split_rows(x.n_rows(), 0.5, cfg.seed);
                        let labels = logistic_labels(&y);
                        validate_tau_grid_logistic(
                            &x, &labels, &groups, &taus, &opts, &split, threads,
                        )
                    });
                    println!(
                        "best tau={} lambda={:.4e} test deviance={:.5e} \
                         misclassification={:.4}",
                        cv.best_tau, cv.best_lambda, cv.best_deviance, cv.best_error
                    );
                }
                FitKind::MultiTask => {
                    let tasks = cfg.tasks;
                    let cv = with_backend!(cfg, data, |x, y, groups| {
                        // The scalar target widens to a task-major n·q
                        // response, exactly as `solve`/`path` do, so the
                        // same dataset drives every subcommand.
                        let split = split_rows(x.n_rows(), 0.5, cfg.seed);
                        let n = x.n_rows();
                        let y = multitask_target(y, n, tasks);
                        validate_tau_grid_multitask(
                            &x, &y, &groups, tasks, &taus, &opts, &split, threads,
                        )
                    });
                    println!(
                        "best tau={} lambda={:.4e} test frobenius={:.5e}",
                        cv.best_tau, cv.best_lambda, cv.best_frobenius
                    );
                }
            }
        }
        "lambda-max" => {
            let data = build_data(&cfg, &scale)?;
            with_backend!(cfg, data, |x, y, groups| {
                let (g_star, lmax) = match cfg.datafit {
                    FitKind::Quadratic => {
                        SglProblem::new(x, y, groups, cfg.tau).lambda_max_argmax()
                    }
                    FitKind::Logistic => {
                        logistic_problem(x, y, groups, cfg.tau).lambda_max_argmax()
                    }
                    FitKind::MultiTask => {
                        multitask_problem(x, y, groups, cfg.tau, cfg.tasks)
                            .lambda_max_argmax()
                    }
                };
                println!("lambda_max = {lmax:.8e} (attained by group {g_star})");
            });
        }
        "compare" => {
            if cfg.datafit != FitKind::Quadratic {
                bail!(
                    "compare times the least-squares-only spheres (static/dynamic/DST3), \
                     so it only runs with --datafit quadratic; {} models are covered by \
                     `cv --datafit {}` and `path --datafit {} --rule gap_safe_seq`",
                    cfg.datafit.name(),
                    cfg.datafit.name(),
                    cfg.datafit.name()
                );
            }
            let data = build_data(&cfg, &scale)?;
            with_backend!(cfg, data, |x, y, groups| {
                let pb = SglProblem::new(x, y, groups, cfg.tau);
                cmd_compare(pb, &cfg, threads)
            });
        }
        "serve" => {
            let data = build_data(&cfg, &scale)?;
            cmd_serve(data, &cfg)?;
        }
        "worker" => {
            // No dataset of its own: everything arrives over the wire,
            // shipped once per dataset and addressed by fingerprint.
            let mut wopts = WorkerOptions::default();
            if let Some(v) = args.get("store-capacity") {
                wopts.dataset_capacity = v.parse().context("--store-capacity")?;
                ensure!(wopts.dataset_capacity >= 1, "--store-capacity must be >= 1");
            }
            if let Some(v) = args.get("progress-ms") {
                let ms: u64 = v.parse().context("--progress-ms")?;
                wopts.progress_interval = std::time::Duration::from_millis(ms);
            }
            let register = args.get("register");
            run_worker_with(
                &args.get_or("listen", "127.0.0.1:7171"),
                wopts,
                register.as_deref(),
            )?;
        }
        "xla" => {
            let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
            let engine = sgl::runtime::engine::XlaEngine::load(&dir)?;
            let meta = engine.meta.clone();
            println!(
                "artifacts: n={} p={} groups={}x{} n_inner={}",
                meta.n, meta.p, meta.n_groups, meta.group_size, meta.n_inner
            );
            let sc = SyntheticConfig {
                n: meta.n,
                n_groups: meta.n_groups,
                group_size: meta.group_size,
                gamma1: 5.min(meta.n_groups),
                gamma2: 4.min(meta.group_size),
                seed: cfg.seed,
                ..Default::default()
            };
            let d = synthetic::generate(&sc);
            let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, cfg.tau);
            let session = engine.session(&pb)?;
            let lambda = args.get_f64("lambda-frac", 0.1) * pb.lambda_max();
            let sw = sgl::util::timer::Stopwatch::start();
            let res = session.solve(lambda, cfg.tol, cfg.max_epochs, None, true)?;
            println!(
                "xla solve: converged={} gap={:.3e} rounds={} time={:.3}s active={}/{}",
                res.converged,
                res.gap,
                res.rounds,
                sw.elapsed_s(),
                res.active_features,
                pb.p()
            );
        }
        other => {
            if other != "help" {
                eprintln!("unknown subcommand {other:?}");
            }
            eprintln!(
                "subcommands: solve | path | cv | lambda-max | compare | serve | worker | xla"
            );
            eprintln!("{}", args.usage());
        }
    }
    // One uniform flush point: whatever the subcommand was (a path solve,
    // the serve demo, a worker that returned cleanly), the buffered events
    // land in a single Chrome trace-event file on the way out.
    if let Some(path) = &cfg.trace_out {
        let n = trace::write_chrome_trace(path)
            .with_context(|| format!("writing trace {path}"))?;
        let dropped = trace::dropped();
        if dropped > 0 {
            println!("trace: {n} events -> {path} ({dropped} dropped at capacity)");
        } else {
            println!("trace: {n} events -> {path}");
        }
    }
    Ok(())
}

fn data_name(cfg: &RunConfig) -> &'static str {
    match cfg.dataset {
        DatasetChoice::Synthetic => "synthetic",
        DatasetChoice::Climate => "climate",
        DatasetChoice::Csv { .. } => "csv",
        DatasetChoice::Libsvm { .. } => "libsvm",
    }
}
