//! `sgl` — the Sparse-Group Lasso solver CLI (Layer-3 entrypoint).
//!
//! Subcommands:
//!
//! - `solve`       one λ on a dataset (native ISTA-BC, Algorithm 2)
//! - `path`        warm-started λ-path (§7.1)
//! - `cv`          (λ, τ)-grid validation (Fig. 3a protocol)
//! - `lambda-max`  critical parameter via Algorithm 1 (Eq. 22)
//! - `compare`     screening-rule timing comparison (Fig. 2c / 3b)
//! - `xla`         solve through the AOT artifacts via PJRT (three-layer path)
//!
//! Datasets come from a config file (`--config run.toml`) or the built-in
//! synthetic/climate generators.

use anyhow::{bail, Context, Result};
use sgl::config::{DatasetChoice, RunConfig};
use sgl::coordinator::jobs::{run_rule_comparison, RuleComparisonJob};
use sgl::coordinator::report::render_rule_timings;
use sgl::data::climate::{self, ClimateConfig};
use sgl::data::synthetic::{self, SyntheticConfig};
use sgl::data::{csvio, Dataset};
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::cv::{split_rows, validate_tau_grid};
use sgl::solver::groups::Groups;
use sgl::solver::path::{solve_path, PathOptions};
use sgl::solver::problem::SglProblem;
use sgl::util::cli::{Args, OptSpec};
use sgl::util::pool::default_threads;

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", help: "TOML config file", takes_value: true, default: None },
        OptSpec { name: "dataset", help: "synthetic|climate", takes_value: true, default: Some("synthetic") },
        OptSpec { name: "tau", help: "l1/group mixing in [0,1]", takes_value: true, default: None },
        OptSpec { name: "lambda-frac", help: "lambda as a fraction of lambda_max", takes_value: true, default: Some("0.1") },
        OptSpec { name: "tol", help: "target duality gap", takes_value: true, default: None },
        OptSpec { name: "rule", help: "none|static|dynamic|dst3|gap_safe|gap_safe_seq", takes_value: true, default: None },
        OptSpec { name: "delta", help: "path grid exponent", takes_value: true, default: None },
        OptSpec { name: "t-count", help: "path grid size", takes_value: true, default: None },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: None },
        OptSpec { name: "threads", help: "worker threads (0 = auto)", takes_value: true, default: None },
        OptSpec { name: "scale", help: "small|paper dataset scale", takes_value: true, default: Some("small") },
        OptSpec { name: "out", help: "output CSV path", takes_value: true, default: None },
        OptSpec { name: "artifacts", help: "artifacts dir for `xla`", takes_value: true, default: Some("artifacts") },
    ]
}

fn main() {
    let args = Args::parse_or_exit(&specs());
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(&path))?,
        None => RunConfig::default(),
    };
    // CLI overrides.
    if let Some(v) = args.get("tau") {
        cfg.tau = v.parse().context("--tau")?;
    }
    if let Some(v) = args.get("tol") {
        cfg.tol = v.parse().context("--tol")?;
    }
    if let Some(v) = args.get("rule") {
        cfg.rule = RuleKind::from_name(&v).with_context(|| format!("unknown rule {v}"))?;
    }
    if let Some(v) = args.get("delta") {
        cfg.delta = v.parse().context("--delta")?;
    }
    if let Some(v) = args.get("t-count") {
        cfg.t_count = v.parse().context("--t-count")?;
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    if let Some(v) = args.get("threads") {
        cfg.threads = v.parse().context("--threads")?;
    }
    if args.get("config").is_none() {
        cfg.dataset = match args.get_or("dataset", "synthetic").as_str() {
            "synthetic" => DatasetChoice::Synthetic,
            "climate" => DatasetChoice::Climate,
            other => bail!("unknown dataset {other} (use a config file for csv)"),
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

fn build_dataset(cfg: &RunConfig, scale: &str) -> Result<Dataset> {
    Ok(match &cfg.dataset {
        DatasetChoice::Synthetic => {
            let sc = if scale == "paper" {
                SyntheticConfig {
                    n: cfg.synth_n,
                    n_groups: cfg.synth_groups,
                    group_size: cfg.synth_group_size,
                    rho: cfg.synth_rho,
                    gamma1: cfg.synth_gamma1,
                    gamma2: cfg.synth_gamma2,
                    seed: cfg.seed,
                    ..Default::default()
                }
            } else {
                SyntheticConfig::small(cfg.seed)
            };
            synthetic::generate(&sc).dataset
        }
        DatasetChoice::Climate => {
            let cc = if scale == "paper" {
                ClimateConfig {
                    grid_lon: cfg.climate_lon,
                    grid_lat: cfg.climate_lat,
                    n_months: cfg.climate_months,
                    seed: cfg.seed,
                    ..Default::default()
                }
            } else {
                ClimateConfig::small(cfg.seed)
            };
            let mut data = climate::generate(&cc);
            climate::preprocess(&mut data);
            data.dataset
        }
        DatasetChoice::Csv { x_path, y_path, group_size } => {
            let x = csvio::read_matrix_csv(std::path::Path::new(x_path))?;
            let y = csvio::read_vector(std::path::Path::new(y_path))?;
            anyhow::ensure!(x.n_cols() % group_size == 0, "p not divisible by group size");
            let groups = Groups::uniform(x.n_cols() / group_size, *group_size);
            Dataset { name: format!("csv({x_path})"), x, y, groups }
        }
    })
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    let cfg = load_config(args)?;
    let scale = args.get_or("scale", "small");
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };

    match cmd {
        "solve" => {
            let data = build_dataset(&cfg, &scale)?;
            let pb = SglProblem::new(data.x, data.y, data.groups, cfg.tau);
            let lambda = args.get_f64("lambda-frac", 0.1) * pb.lambda_max();
            let opts = SolveOptions {
                tol: cfg.tol,
                fce: cfg.fce,
                max_epochs: cfg.max_epochs,
                rule: cfg.rule,
                record_history: true,
            };
            let res = solve(&pb, lambda, None, &opts);
            let y2: f64 = pb.y.iter().map(|v| v * v).sum();
            println!(
                "dataset={} n={} p={} lambda={lambda:.5e}",
                data_name(&cfg),
                pb.n(),
                pb.p()
            );
            println!(
                "converged={} gap={:.3e} (rel {:.2e}) epochs={} time={:.3}s \
                 active_features={} active_groups={}",
                res.converged,
                res.gap,
                res.gap / y2,
                res.epochs,
                res.elapsed_s,
                res.active.n_active_features(),
                res.active.n_active_groups()
            );
        }
        "path" => {
            let data = build_dataset(&cfg, &scale)?;
            let pb = SglProblem::new(data.x, data.y, data.groups, cfg.tau);
            let opts = PathOptions {
                delta: cfg.delta,
                t_count: cfg.t_count,
                solve: SolveOptions {
                    tol: cfg.tol,
                    fce: cfg.fce,
                    max_epochs: cfg.max_epochs,
                    rule: cfg.rule,
                    record_history: false,
                },
            };
            let path = solve_path(&pb, &opts);
            println!(
                "path: {} lambdas, rule={}, total {:.3}s, epochs={}, all converged={}",
                path.lambdas.len(),
                cfg.rule.name(),
                path.total_s,
                path.total_epochs(),
                path.all_converged()
            );
            if let Some(out) = args.get("out") {
                let rows: Vec<Vec<f64>> = path
                    .lambdas
                    .iter()
                    .zip(&path.results)
                    .map(|(l, r)| {
                        vec![
                            *l,
                            r.gap,
                            r.epochs as f64,
                            r.active.n_active_features() as f64,
                            r.active.n_active_groups() as f64,
                        ]
                    })
                    .collect();
                csvio::write_csv(
                    std::path::Path::new(&out),
                    &["lambda", "gap", "epochs", "active_features", "active_groups"],
                    &rows,
                )?;
                println!("wrote {out}");
            }
        }
        "cv" => {
            let data = build_dataset(&cfg, &scale)?;
            let taus: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
            let split = split_rows(data.x.n_rows(), 0.5, cfg.seed);
            let opts = PathOptions {
                delta: cfg.delta,
                t_count: cfg.t_count,
                solve: SolveOptions { tol: cfg.tol, record_history: false, ..Default::default() },
            };
            let cv =
                validate_tau_grid(&data.x, &data.y, &data.groups, &taus, &opts, &split, threads);
            println!(
                "best tau={} lambda={:.4e} test mse={:.5e}",
                cv.best_tau, cv.best_lambda, cv.best_mse
            );
        }
        "lambda-max" => {
            let data = build_dataset(&cfg, &scale)?;
            let pb = SglProblem::new(data.x, data.y, data.groups, cfg.tau);
            let (g_star, lmax) = pb.lambda_max_argmax();
            println!("lambda_max = {lmax:.8e} (attained by group {g_star})");
        }
        "compare" => {
            let data = build_dataset(&cfg, &scale)?;
            let pb = SglProblem::new(data.x, data.y, data.groups, cfg.tau);
            let job = RuleComparisonJob {
                tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
                delta: cfg.delta,
                t_count: cfg.t_count,
                fce: cfg.fce,
                max_epochs: cfg.max_epochs,
                ..Default::default()
            };
            let timings = run_rule_comparison(std::sync::Arc::new(pb), &job, threads, None);
            println!("{}", render_rule_timings(&timings));
        }
        "xla" => {
            let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
            let engine = sgl::runtime::engine::XlaEngine::load(&dir)?;
            let meta = engine.meta.clone();
            println!(
                "artifacts: n={} p={} groups={}x{} n_inner={}",
                meta.n, meta.p, meta.n_groups, meta.group_size, meta.n_inner
            );
            let sc = SyntheticConfig {
                n: meta.n,
                n_groups: meta.n_groups,
                group_size: meta.group_size,
                gamma1: 5.min(meta.n_groups),
                gamma2: 4.min(meta.group_size),
                seed: cfg.seed,
                ..Default::default()
            };
            let d = synthetic::generate(&sc);
            let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, cfg.tau);
            let session = engine.session(&pb)?;
            let lambda = args.get_f64("lambda-frac", 0.1) * pb.lambda_max();
            let sw = sgl::util::timer::Stopwatch::start();
            let res = session.solve(lambda, cfg.tol, cfg.max_epochs, None, true)?;
            println!(
                "xla solve: converged={} gap={:.3e} rounds={} time={:.3}s active={}/{}",
                res.converged,
                res.gap,
                res.rounds,
                sw.elapsed_s(),
                res.active_features,
                pb.p()
            );
        }
        other => {
            if other != "help" {
                eprintln!("unknown subcommand {other:?}");
            }
            eprintln!("subcommands: solve | path | cv | lambda-max | compare | xla");
            eprintln!("{}", args.usage());
        }
    }
    Ok(())
}

fn data_name(cfg: &RunConfig) -> &'static str {
    match cfg.dataset {
        DatasetChoice::Synthetic => "synthetic",
        DatasetChoice::Climate => "climate",
        DatasetChoice::Csv { .. } => "csv",
    }
}
