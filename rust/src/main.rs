//! `sgl` — the Sparse-Group Lasso solver CLI (Layer-3 entrypoint).
//!
//! Subcommands:
//!
//! - `solve`       one λ on a dataset (native solver, Algorithm 2)
//! - `path`        warm-started λ-path (§7.1)
//! - `cv`          (λ, τ)-grid validation (Fig. 3a protocol)
//! - `lambda-max`  critical parameter via Algorithm 1 (Eq. 22)
//! - `compare`     screening-rule timing comparison (Fig. 2c / 3b)
//! - `xla`         solve through the AOT artifacts via PJRT (three-layer path)
//!
//! Datasets come from a config file (`--config run.toml`) or the built-in
//! synthetic/climate generators. `--design dense|csc` selects the design
//! backend (CSC stores only the nonzero entries, so epochs cost `O(nnz)`),
//! `--algo cd|ista|fista` the inner solver; both are also available as
//! `[dataset] design` / `[solver] algo` TOML keys.

use anyhow::{bail, Context, Result};
use sgl::config::{
    parse_design_backend, DatasetChoice, DesignBackend, RunConfig, UnknownBackendError,
};
use sgl::coordinator::jobs::{run_rule_comparison, RuleComparisonJob};
use sgl::coordinator::report::render_rule_timings;
use sgl::data::climate::{self, ClimateConfig};
use sgl::data::synthetic::{self, SyntheticConfig};
use sgl::data::{csvio, Dataset};
use sgl::linalg::{CscMatrix, Design};
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::cv::{split_rows, validate_tau_grid};
use sgl::solver::groups::Groups;
use sgl::solver::path::{solve_path_with, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use sgl::util::cli::{Args, OptSpec};
use sgl::util::pool::default_threads;

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", help: "TOML config file", takes_value: true, default: None },
        OptSpec { name: "dataset", help: "synthetic|climate", takes_value: true, default: Some("synthetic") },
        OptSpec { name: "design", help: "dense|csc design backend", takes_value: true, default: None },
        OptSpec { name: "algo", help: "cd|ista|fista inner solver", takes_value: true, default: None },
        OptSpec { name: "tau", help: "l1/group mixing in [0,1]", takes_value: true, default: None },
        OptSpec { name: "lambda-frac", help: "lambda as a fraction of lambda_max", takes_value: true, default: Some("0.1") },
        OptSpec { name: "tol", help: "target duality gap", takes_value: true, default: None },
        OptSpec { name: "rule", help: "none|static|dynamic|dst3|gap_safe|gap_safe_seq", takes_value: true, default: None },
        OptSpec { name: "delta", help: "path grid exponent", takes_value: true, default: None },
        OptSpec { name: "t-count", help: "path grid size", takes_value: true, default: None },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: None },
        OptSpec { name: "threads", help: "worker threads (0 = auto)", takes_value: true, default: None },
        OptSpec { name: "scale", help: "small|paper dataset scale", takes_value: true, default: Some("small") },
        OptSpec { name: "out", help: "output CSV path", takes_value: true, default: None },
        OptSpec { name: "artifacts", help: "artifacts dir for `xla`", takes_value: true, default: Some("artifacts") },
    ]
}

fn main() {
    let args = Args::parse_or_exit(&specs());
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        if let Some(ub) = e.downcast_ref::<UnknownBackendError>() {
            eprintln!(
                "hint: {:?} is not a design backend; valid choices are: dense, csc",
                ub.given
            );
        }
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(&path))?,
        None => RunConfig::default(),
    };
    // CLI overrides.
    if let Some(v) = args.get("design") {
        cfg.design = parse_design_backend(&v).context("--design")?;
    }
    if let Some(v) = args.get("algo") {
        cfg.algo = SolverKind::from_name(&v)
            .with_context(|| format!("unknown --algo {v} (cd|ista|fista)"))?;
    }
    if let Some(v) = args.get("tau") {
        cfg.tau = v.parse().context("--tau")?;
    }
    if let Some(v) = args.get("tol") {
        cfg.tol = v.parse().context("--tol")?;
    }
    if let Some(v) = args.get("rule") {
        cfg.rule = RuleKind::from_name(&v).with_context(|| format!("unknown rule {v}"))?;
    }
    if let Some(v) = args.get("delta") {
        cfg.delta = v.parse().context("--delta")?;
    }
    if let Some(v) = args.get("t-count") {
        cfg.t_count = v.parse().context("--t-count")?;
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    if let Some(v) = args.get("threads") {
        cfg.threads = v.parse().context("--threads")?;
    }
    if args.get("config").is_none() {
        cfg.dataset = match args.get_or("dataset", "synthetic").as_str() {
            "synthetic" => DatasetChoice::Synthetic,
            "climate" => DatasetChoice::Climate,
            other => bail!("unknown dataset {other} (use a config file for csv)"),
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

fn build_dataset(cfg: &RunConfig, scale: &str) -> Result<Dataset> {
    Ok(match &cfg.dataset {
        DatasetChoice::Synthetic => {
            let sc = if scale == "paper" {
                SyntheticConfig {
                    n: cfg.synth_n,
                    n_groups: cfg.synth_groups,
                    group_size: cfg.synth_group_size,
                    rho: cfg.synth_rho,
                    gamma1: cfg.synth_gamma1,
                    gamma2: cfg.synth_gamma2,
                    seed: cfg.seed,
                    ..Default::default()
                }
            } else {
                SyntheticConfig::small(cfg.seed)
            };
            synthetic::generate(&sc).dataset
        }
        DatasetChoice::Climate => {
            let cc = if scale == "paper" {
                ClimateConfig {
                    grid_lon: cfg.climate_lon,
                    grid_lat: cfg.climate_lat,
                    n_months: cfg.climate_months,
                    seed: cfg.seed,
                    ..Default::default()
                }
            } else {
                ClimateConfig::small(cfg.seed)
            };
            let mut data = climate::generate(&cc);
            climate::preprocess(&mut data);
            data.dataset
        }
        DatasetChoice::Csv { x_path, y_path, group_size } => {
            let x = csvio::read_matrix_csv(std::path::Path::new(x_path))?;
            let y = csvio::read_vector(std::path::Path::new(y_path))?;
            anyhow::ensure!(x.n_cols() % group_size == 0, "p not divisible by group size");
            let groups = Groups::uniform(x.n_cols() / group_size, *group_size);
            Dataset { name: format!("csv({x_path})"), x, y, groups }
        }
    })
}

/// `solve` on any backend.
fn cmd_solve<D: Design>(pb: &SglProblem<D>, cfg: &RunConfig, args: &Args, name: &str) {
    let lambda = args.get_f64("lambda-frac", 0.1) * pb.lambda_max();
    let opts = SolveOptions {
        tol: cfg.tol,
        fce: cfg.fce,
        max_epochs: cfg.max_epochs,
        rule: cfg.rule,
        record_history: true,
    };
    let res = match cfg.algo {
        SolverKind::Cd => sgl::solver::cd::solve(pb, lambda, None, &opts),
        SolverKind::Ista => sgl::solver::ista::solve_ista(pb, lambda, None, &opts),
        SolverKind::Fista => sgl::solver::fista::solve_fista(pb, lambda, None, &opts),
    };
    let y2: f64 = pb.y.iter().map(|v| v * v).sum();
    println!(
        "dataset={} design={} algo={} n={} p={} nnz={} lambda={lambda:.5e}",
        name,
        cfg.design.name(),
        cfg.algo.name(),
        pb.n(),
        pb.p(),
        pb.x.nnz()
    );
    println!(
        "converged={} gap={:.3e} (rel {:.2e}) epochs={} time={:.3}s \
         active_features={} active_groups={}",
        res.converged,
        res.gap,
        res.gap / y2,
        res.epochs,
        res.elapsed_s,
        res.active.n_active_features(),
        res.active.n_active_groups()
    );
}

/// `path` on any backend.
fn cmd_path<D: Design>(pb: &SglProblem<D>, cfg: &RunConfig, args: &Args) -> Result<()> {
    let opts = PathOptions {
        delta: cfg.delta,
        t_count: cfg.t_count,
        solve: SolveOptions {
            tol: cfg.tol,
            fce: cfg.fce,
            max_epochs: cfg.max_epochs,
            rule: cfg.rule,
            record_history: false,
        },
    };
    let lambdas = lambda_grid(pb.lambda_max(), opts.delta, opts.t_count);
    let path = solve_path_with(pb, &lambdas, &opts, cfg.algo);
    println!(
        "path: {} lambdas, design={}, algo={}, rule={}, total {:.3}s, epochs={}, \
         all converged={}",
        path.lambdas.len(),
        cfg.design.name(),
        cfg.algo.name(),
        cfg.rule.name(),
        path.total_s,
        path.total_epochs(),
        path.all_converged()
    );
    if let Some(out) = args.get("out") {
        let rows: Vec<Vec<f64>> = path
            .lambdas
            .iter()
            .zip(&path.results)
            .map(|(l, r)| {
                vec![
                    *l,
                    r.gap,
                    r.epochs as f64,
                    r.active.n_active_features() as f64,
                    r.active.n_active_groups() as f64,
                ]
            })
            .collect();
        csvio::write_csv(
            std::path::Path::new(&out),
            &["lambda", "gap", "epochs", "active_features", "active_groups"],
            &rows,
        )?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `compare` on any backend.
fn cmd_compare<D: Design>(pb: SglProblem<D>, cfg: &RunConfig, threads: usize) {
    let job = RuleComparisonJob {
        tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
        delta: cfg.delta,
        t_count: cfg.t_count,
        fce: cfg.fce,
        max_epochs: cfg.max_epochs,
        serial_timing: true,
        ..Default::default()
    };
    let timings = run_rule_comparison(std::sync::Arc::new(pb), &job, threads, None);
    println!("{}", render_rule_timings(&timings));
}

/// Build the problem on the configured backend and run `$body` with `$pb`
/// bound to it — the one place the dense/CSC choice is expanded, so every
/// subcommand stays backend-complete by construction. (`$body` is
/// monomorphized once per backend through the generic `cmd_*` helpers.)
macro_rules! with_design {
    ($cfg:expr, $data:expr, |$pb:ident| $body:expr) => {{
        let data = $data;
        match $cfg.design {
            DesignBackend::Dense => {
                let $pb = SglProblem::new(data.x, data.y, data.groups, $cfg.tau);
                $body
            }
            DesignBackend::Csc => {
                let x = CscMatrix::from_dense(&data.x);
                let $pb = SglProblem::new(x, data.y, data.groups, $cfg.tau);
                $body
            }
        }
    }};
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    let cfg = load_config(args)?;
    let scale = args.get_or("scale", "small");
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };

    match cmd {
        "solve" => {
            let data = build_dataset(&cfg, &scale)?;
            let name = data_name(&cfg);
            with_design!(cfg, data, |pb| cmd_solve(&pb, &cfg, args, name));
        }
        "path" => {
            let data = build_dataset(&cfg, &scale)?;
            with_design!(cfg, data, |pb| cmd_path(&pb, &cfg, args)?);
        }
        "cv" => {
            let data = build_dataset(&cfg, &scale)?;
            let taus: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
            let split = split_rows(data.x.n_rows(), 0.5, cfg.seed);
            let opts = PathOptions {
                delta: cfg.delta,
                t_count: cfg.t_count,
                solve: SolveOptions { tol: cfg.tol, record_history: false, ..Default::default() },
            };
            let cv = match cfg.design {
                DesignBackend::Dense => {
                    validate_tau_grid(&data.x, &data.y, &data.groups, &taus, &opts, &split, threads)
                }
                DesignBackend::Csc => {
                    let x = CscMatrix::from_dense(&data.x);
                    validate_tau_grid(&x, &data.y, &data.groups, &taus, &opts, &split, threads)
                }
            };
            println!(
                "best tau={} lambda={:.4e} test mse={:.5e}",
                cv.best_tau, cv.best_lambda, cv.best_mse
            );
        }
        "lambda-max" => {
            let data = build_dataset(&cfg, &scale)?;
            let pb = SglProblem::new(data.x, data.y, data.groups, cfg.tau);
            let (g_star, lmax) = pb.lambda_max_argmax();
            println!("lambda_max = {lmax:.8e} (attained by group {g_star})");
        }
        "compare" => {
            let data = build_dataset(&cfg, &scale)?;
            with_design!(cfg, data, |pb| cmd_compare(pb, &cfg, threads));
        }
        "xla" => {
            let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
            let engine = sgl::runtime::engine::XlaEngine::load(&dir)?;
            let meta = engine.meta.clone();
            println!(
                "artifacts: n={} p={} groups={}x{} n_inner={}",
                meta.n, meta.p, meta.n_groups, meta.group_size, meta.n_inner
            );
            let sc = SyntheticConfig {
                n: meta.n,
                n_groups: meta.n_groups,
                group_size: meta.group_size,
                gamma1: 5.min(meta.n_groups),
                gamma2: 4.min(meta.group_size),
                seed: cfg.seed,
                ..Default::default()
            };
            let d = synthetic::generate(&sc);
            let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, cfg.tau);
            let session = engine.session(&pb)?;
            let lambda = args.get_f64("lambda-frac", 0.1) * pb.lambda_max();
            let sw = sgl::util::timer::Stopwatch::start();
            let res = session.solve(lambda, cfg.tol, cfg.max_epochs, None, true)?;
            println!(
                "xla solve: converged={} gap={:.3e} rounds={} time={:.3}s active={}/{}",
                res.converged,
                res.gap,
                res.rounds,
                sw.elapsed_s(),
                res.active_features,
                pb.p()
            );
        }
        other => {
            if other != "help" {
                eprintln!("unknown subcommand {other:?}");
            }
            eprintln!("subcommands: solve | path | cv | lambda-max | compare | xla");
            eprintln!("{}", args.usage());
        }
    }
    Ok(())
}

fn data_name(cfg: &RunConfig) -> &'static str {
    match cfg.dataset {
        DatasetChoice::Synthetic => "synthetic",
        DatasetChoice::Climate => "climate",
        DatasetChoice::Csv { .. } => "csv",
    }
}
