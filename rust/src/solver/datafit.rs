//! The `Datafit` abstraction: what the solver × screening × serving stack
//! needs from the smooth loss `f` in `min_β f(β) + λ Ω(β)`.
//!
//! The GAP safe machinery of the source paper is not tied to the quadratic
//! loss: the journal follow-up (Ndiaye et al., "Gap Safe screening rules
//! for sparsity enforcing penalties", arXiv 1611.05780) derives the same
//! dual-gap spheres for any smooth datafit with a Lipschitz gradient. This
//! module is the seam that makes the crate generic over that choice, the
//! way [`crate::linalg::design::Design`] made it generic over the matrix
//! storage:
//!
//! - [`Quadratic`] — the extracted least-squares behavior the crate
//!   started with, `f(β) = ½‖y − Xβ‖²` (+ an optional ridge term
//!   `½μ‖β‖²` that realizes the elastic net *without* the historical
//!   `[X; √μ I]` row-stacking trick);
//! - [`Logistic`] — sparse-group logistic regression,
//!   `f(β) = Σᵢ log(1 + exp(xᵢᵀβ)) − yᵢ xᵢᵀβ` with labels `yᵢ ∈ [0, 1]`;
//! - [`MultiTaskQuadratic`] — multi-response least squares
//!   `f(B) = ½‖Y − XB‖_F²` with `Y ∈ R^{n×q}` (Ndiaye et al., "GAP Safe
//!   screening rules for sparse multi-task and multi-class models",
//!   arXiv 1506.03736): the residual becomes a matrix, per-feature
//!   screening scores become block **row norms**, and the same dual-gap
//!   radius applies verbatim to the Frobenius geometry.
//!
//! # Matrix-valued state (the multi-task contract)
//!
//! [`FitState`] is flattened matrix state. Every implementer must hold
//! these layout invariants, which all solvers/screens assume:
//!
//! - **n-dimensional state is task-major.** `main`, `aux`, the response
//!   `y`, and dual points `θ` have length `n·q`, laid out as `q` stacked
//!   n-vectors: task `t` occupies `[t·n, (t+1)·n)`. Column kernels
//!   (`col_dot`, `col_axpy`, `matvec`) then operate per task on plain
//!   n-slices, and flat ℓ2 norms *are* Frobenius norms.
//! - **p-dimensional state is feature-major.** Coefficients `β`,
//!   correlations `XᵀR`, and sphere centers `XᵀΘ` have length `p·q`, laid
//!   out row-major as `p` rows of `q` tasks: feature `j` occupies
//!   `[j·q, (j+1)·q)`. Row norms, the row-block prox, and screening
//!   zeroing then operate on contiguous slices.
//! - **`q = 1` is byte-identical to the scalar layout.** Both conventions
//!   degenerate to today's plain vectors, so a `tasks() == 1` datafit runs
//!   the exact scalar code paths — this is what makes
//!   `MultiTaskQuadratic { tasks: 1 }` bit-identical to [`Quadratic`]
//!   (pinned by `tests/datafit_multitask.rs`).
//!
//! A datafit advertises its response width via [`Datafit::tasks`]
//! (default 1); problems validate `y.len() == n · tasks` at construction.
//!
//! # The screening-safety contract
//!
//! Theorem 1 of the source paper discards a group/feature whenever a test
//! over a *safe sphere* — a ball certified to contain the dual optimum
//! `θ*` — passes. The sphere comes from two datafit-supplied ingredients,
//! and both carry correctness obligations:
//!
//! 1. **Dual scaling.** The solver builds a dual point by rescaling the
//!    generalized residual `r = −∇f(Xβ)` as `θ = r / s` with
//!    `s = max(λ, Ω^D(Xᵀθ·s))`. For the resulting sphere to be *safe*, `θ`
//!    must be **dual feasible**: `Ω^D` of the (datafit-adjusted, see
//!    [`Datafit::adjust_xt`]) correlation vector must be ≤ λ after
//!    scaling, and `θ` must lie in the domain of the conjugate loss
//!    (`y − λθ ∈ [0, 1]` coordinatewise for [`Logistic`]). Moreover
//!    [`crate::screening::gap_safe::GapSafeSeqRule`] *replays* a stored
//!    `θ` at the **next, smaller** λ′ ≤ λ of a path — so feasibility must
//!    survive shrinking λ. Both shipped datafits guarantee this because
//!    `λ′/s ≤ λ/s ≤ 1` keeps the rescaled point a convex combination of
//!    feasible points; a new datafit must uphold the same invariant or
//!    sequential screening becomes unsafe (it would delete features that
//!    are active at the optimum — silently wrong results, not slow ones).
//! 2. **Curvature.** [`Datafit::curvature`] is the constant `c` in the
//!    radius `r = √(2·c·gap) / λ`, valid iff the dual objective is
//!    `λ²/c`-strongly concave over its domain. Quadratic: `c = 1`
//!    (the dual is exactly `λ²`-strongly concave). Logistic: the conjugate
//!    of the logit loss has second derivative `1/(v(1−v)) ≥ 4`, so the
//!    dual is `4λ²`-strongly concave and `c = ¼`. Overstating `c` inflates
//!    the sphere (slow but safe); *understating* it is unsafe.
//!
//! Everything else the trait exposes (per-column/per-group gradient
//! Lipschitz scaling, the CD majorization hooks, the λ_max residual) only
//! affects convergence speed, not safety.
//!
//! # Intercept handling
//!
//! Neither shipped datafit fits an intercept; callers center `y` (and
//! columns) upstream, as in the source paper's experiments. The trait is
//! deliberately intercept-free for now — an unpenalized intercept touches
//! the dual feasibility set and is left to a future PR.

use std::borrow::Cow;

use crate::linalg::design::Design;
use crate::linalg::ops::l2_norm_sq;

/// Which datafit a problem uses — the config/CLI/wire-facing enumeration
/// (mirrors `screening::RuleKind` and `config::DesignBackend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitKind {
    /// Least squares `½‖y − Xβ‖²` (optionally ridge-augmented).
    Quadratic,
    /// Binary logistic regression with labels in `[0, 1]`.
    Logistic,
    /// Multi-response least squares `½‖Y − XB‖_F²`, `Y ∈ R^{n×q}`.
    MultiTask,
}

impl FitKind {
    /// Stable lowercase name used by configs, the CLI and the wire codec.
    pub fn name(self) -> &'static str {
        match self {
            FitKind::Quadratic => "quadratic",
            FitKind::Logistic => "logistic",
            FitKind::MultiTask => "multitask",
        }
    }

    /// Every supported datafit, for help strings and validation messages.
    pub fn all() -> &'static [FitKind] {
        &[FitKind::Quadratic, FitKind::Logistic, FitKind::MultiTask]
    }

    /// Parse a [`FitKind::name`] back (case-sensitive, like `RuleKind`).
    pub fn from_name(s: &str) -> Option<FitKind> {
        FitKind::all().iter().copied().find(|k| k.name() == s)
    }
}

/// Per-solve iterate state a solver threads through its epochs.
///
/// The coordinate-descent hot loop maintains one n-vector incrementally
/// (`main`), updating it by `±δ·X_j` as coefficients move. What that
/// vector *is* depends on the datafit:
///
/// - [`Quadratic`]: `main = ρ = y − Xβ` (the residual itself; `aux` is
///   `None` and [`FitState::residual`] borrows `main` directly — zero
///   overhead versus the historical code);
/// - [`Logistic`]: `main = Xβ` (the linear predictor, which *is* the
///   quantity that moves linearly in β), with `aux = y − σ(Xβ)` — the
///   negative gradient — refreshed via [`Datafit::sync_residual`]
///   whenever `main` changed;
/// - [`MultiTaskQuadratic`]: `main = R = Y − XB` flattened **task-major**
///   (length `n·q`; task `t` is the n-slice `[t·n, (t+1)·n)`), so each
///   task behaves exactly like a scalar quadratic residual under the
///   column kernels. See the [module docs](self) for the full
///   matrix-state layout contract.
#[derive(Clone, Debug, PartialEq)]
pub struct FitState {
    /// The incrementally-maintained vector (see type docs).
    pub main: Vec<f64>,
    /// The derived generalized residual when `main` is not already it.
    pub aux: Option<Vec<f64>>,
}

impl FitState {
    /// The generalized residual `r = −∇f(Xβ)` — the vector whose
    /// correlations `Xᵀr` drive both the solver steps and the dual point.
    #[inline]
    pub fn residual(&self) -> &[f64] {
        self.aux.as_deref().unwrap_or(&self.main)
    }

    /// Borrowed view for snapshot construction.
    #[inline]
    pub fn as_ref(&self) -> StateRef<'_> {
        StateRef { main: &self.main, resid: self.residual() }
    }
}

/// Borrowed view of a [`FitState`] (or of a bare residual slice, for the
/// quadratic-only legacy entry points where `main` *is* the residual).
#[derive(Clone, Copy)]
pub struct StateRef<'a> {
    /// See [`FitState::main`].
    pub main: &'a [f64],
    /// See [`FitState::residual`].
    pub resid: &'a [f64],
}

/// A smooth datafit `f` with everything GAP safe screening needs: state
/// maintenance for the solvers, loss/dual evaluation for the gap, and the
/// scaling/curvature constants whose contract is documented at the
/// [module level](self).
///
/// `Quadratic` behavior is the crate's historical behavior bit-for-bit:
/// every method either reduces to the old arithmetic exactly or is gated
/// behind `ridge != 0` / `grad_lip_scale != 1` guards.
pub trait Datafit: Clone + Send + Sync + std::fmt::Debug + 'static {
    /// The config/wire-facing tag for this datafit.
    fn kind(&self) -> FitKind;

    /// `true` iff [`FitState::main`] is itself the generalized residual
    /// (no `aux`, no [`Datafit::sync_residual`] work). The legacy
    /// residual-slice entry points in `duality`/`screening` assert this.
    fn state_is_residual(&self) -> bool;

    /// Number of response columns `q` (the width of `Y`). `1` for every
    /// scalar datafit. A `q > 1` datafit commits to the flattened
    /// matrix-state layout documented at the [module level](self):
    /// n-dimensional state task-major, p-dimensional state feature-major,
    /// with `q = 1` degenerating byte-identically to the scalar vectors.
    fn tasks(&self) -> usize {
        1
    }

    /// Factor applied to the quadratic-case Lipschitz constants
    /// `‖X_g‖₂²`: `1` for least squares, `¼` for logistic (the logistic
    /// Hessian satisfies `∇²f ⪯ ¼ XᵀX`). Folded into
    /// `SglProblem::lipschitz` at construction so the CD hot loop is
    /// untouched.
    fn grad_lip_scale(&self) -> f64 {
        1.0
    }

    /// The constant `c` in the safe radius `√(2·c·gap)/λ`; see the
    /// [module docs](self) for the strong-concavity obligation.
    fn curvature(&self) -> f64 {
        1.0
    }

    /// The ℓ2 (elastic-net) coefficient `μ` in `f + ½μ‖β‖²`; `0` when
    /// absent. Nonzero only for [`Quadratic`].
    fn ridge(&self) -> f64 {
        0.0
    }

    /// Validate the label vector at problem construction (logistic
    /// requires `y ∈ [0, 1]`; quadratic accepts anything finite-ish).
    fn validate_y(&self, _y: &[f64]) {}

    /// The generalized residual at `β = 0` — the vector whose dual norm
    /// of correlations defines `λ_max = Ω^D(Xᵀ·zero_residual(y))`.
    /// Quadratic: `y` itself (borrowed). Logistic: `y − ½`.
    fn zero_residual<'a>(&self, y: &'a [f64]) -> Cow<'a, [f64]>;

    /// Scale of the objective at `β = 0`, used to turn the relative
    /// tolerance into an absolute gap threshold. Quadratic: `‖y‖²`
    /// (the historical choice, kept bit-identical). Logistic: `n·ln 2`
    /// (= the primal value at `β = 0`).
    fn gap_scale(&self, y: &[f64]) -> f64;

    /// `f(β)` evaluated from the maintained state: `main` is
    /// [`FitState::main`] for this datafit (the residual for quadratic,
    /// the linear predictor for logistic).
    fn loss(&self, y: &[f64], main: &[f64], beta: &[f64]) -> f64;

    /// Dual objective at the (already-scaled) dual point `θ`.
    /// `theta_aug_sq` is [`Datafit::theta_aug_sq`] for the same `β`/scale
    /// — the squared norm of the implicit ridge-block coordinates of `θ`
    /// (always `0` when `ridge() == 0`).
    fn dual_at(&self, y: &[f64], theta: &[f64], theta_aug_sq: f64, lambda: f64) -> f64;

    /// Squared norm of the implicit augmented-block dual coordinates
    /// `θ_aug = −√μ·β / scale` (ridge quadratic only; `0` otherwise).
    fn theta_aug_sq(&self, beta: &[f64], scale: f64) -> f64 {
        let _ = (beta, scale);
        0.0
    }

    /// Adjust a raw correlation vector `Xᵀr` into the full gradient-based
    /// correlation the dual norm and sphere center must see. Identity
    /// unless `ridge() != 0`, where it becomes `Xᵀr − μβ` (the implicit
    /// `[X; √μI]ᵀ[ρ; −√μβ]` without materializing the stacked rows).
    fn adjust_xt<'a>(&self, xt: &'a [f64], beta: &'a [f64]) -> Cow<'a, [f64]>;

    /// Per-coordinate CD correction: map the raw correlation
    /// `corr = X_jᵀr` to the negative partial derivative used by the
    /// majorized CD step. Identity unless `ridge() != 0` (then
    /// `corr − μ·β_j`).
    fn grad_correction(&self, corr: f64, bj: f64) -> f64 {
        let _ = bj;
        corr
    }

    /// Sign with which a coefficient change `δ` enters `main`:
    /// `main += delta_sign()·δ·X_j`. `−1` for the residual
    /// (`ρ −= δX_j`), `+1` for the linear predictor (`Xβ += δX_j`).
    fn delta_sign(&self) -> f64;

    /// Recompute `aux` (the generalized residual) from `main`. No-op when
    /// [`Datafit::state_is_residual`]. Solvers call this after every batch
    /// of `main` updates and before the next read of
    /// [`FitState::residual`].
    fn sync_residual(&self, y: &[f64], state: &mut FitState);

    /// Whether the speculative parallel CD epoch
    /// (`sweep::cd_epoch_parallel`) is sound for this datafit. Its
    /// accept/revert test measures `½Δ‖ρ‖²`, which is quadratic-specific,
    /// so only the plain (`ridge == 0`) quadratic datafit opts in.
    fn supports_parallel_cd(&self) -> bool;

    /// Build the solver state for a (possibly warm) start `β`, exactly
    /// replicating the historical residual initialization in the
    /// quadratic case.
    fn init_state<D: Design>(&self, x: &D, y: &[f64], beta: &[f64]) -> FitState;
}

/// Least squares `½‖y − Xβ‖²`, optionally with a ridge term `½μ‖β‖²`
/// that realizes the elastic net through the datafit instead of the
/// historical `[X; √μI]` row-stacking (see
/// [`crate::solver::elastic_net`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Quadratic {
    /// The ℓ2 coefficient `μ ≥ 0` (`0` = plain least squares).
    pub ridge: f64,
}

impl Quadratic {
    /// Ridge-augmented least squares (elastic net datafit).
    pub fn with_ridge(lambda2: f64) -> Quadratic {
        assert!(lambda2.is_finite() && lambda2 >= 0.0, "ridge must be finite and >= 0");
        Quadratic { ridge: lambda2 }
    }
}

impl Datafit for Quadratic {
    fn kind(&self) -> FitKind {
        FitKind::Quadratic
    }

    fn state_is_residual(&self) -> bool {
        true
    }

    fn ridge(&self) -> f64 {
        self.ridge
    }

    fn zero_residual<'a>(&self, y: &'a [f64]) -> Cow<'a, [f64]> {
        Cow::Borrowed(y)
    }

    fn gap_scale(&self, y: &[f64]) -> f64 {
        l2_norm_sq(y)
    }

    fn loss(&self, _y: &[f64], main: &[f64], beta: &[f64]) -> f64 {
        let mut v = 0.5 * l2_norm_sq(main);
        if self.ridge != 0.0 {
            v += 0.5 * self.ridge * l2_norm_sq(beta);
        }
        v
    }

    fn dual_at(&self, y: &[f64], theta: &[f64], theta_aug_sq: f64, lambda: f64) -> f64 {
        let d = crate::solver::duality::dual_value(y, theta, lambda);
        if theta_aug_sq != 0.0 {
            d - 0.5 * lambda * lambda * theta_aug_sq
        } else {
            d
        }
    }

    fn theta_aug_sq(&self, beta: &[f64], scale: f64) -> f64 {
        if self.ridge == 0.0 {
            0.0
        } else {
            self.ridge * l2_norm_sq(beta) / (scale * scale)
        }
    }

    fn adjust_xt<'a>(&self, xt: &'a [f64], beta: &'a [f64]) -> Cow<'a, [f64]> {
        if self.ridge == 0.0 {
            return Cow::Borrowed(xt);
        }
        Cow::Owned(xt.iter().zip(beta).map(|(x, b)| x - self.ridge * b).collect())
    }

    fn grad_correction(&self, corr: f64, bj: f64) -> f64 {
        if self.ridge == 0.0 {
            corr
        } else {
            corr - self.ridge * bj
        }
    }

    fn delta_sign(&self) -> f64 {
        -1.0
    }

    fn sync_residual(&self, _y: &[f64], _state: &mut FitState) {}

    fn supports_parallel_cd(&self) -> bool {
        self.ridge == 0.0
    }

    fn init_state<D: Design>(&self, x: &D, y: &[f64], beta: &[f64]) -> FitState {
        // Exactly the historical warm-start residual: start from y, and
        // only touch it when the start is actually warm.
        let mut main = y.to_vec();
        if beta.iter().any(|&b| b != 0.0) {
            let xb = x.matvec(beta);
            for (r, v) in main.iter_mut().zip(&xb) {
                *r -= v;
            }
        }
        FitState { main, aux: None }
    }
}

/// Binary logistic regression,
/// `f(β) = Σᵢ softplus(xᵢᵀβ) − yᵢ·xᵢᵀβ`, labels `yᵢ ∈ [0, 1]`.
///
/// The generalized residual is `r = y − σ(Xβ)`, so the solver sweeps keep
/// the exact shape of the least-squares ones (`Xᵀr` correlations, `L_g`
/// majorization with the folded `¼` Hessian bound); only the state
/// refresh differs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Logistic;

impl Datafit for Logistic {
    fn kind(&self) -> FitKind {
        FitKind::Logistic
    }

    fn state_is_residual(&self) -> bool {
        false
    }

    fn grad_lip_scale(&self) -> f64 {
        0.25
    }

    fn curvature(&self) -> f64 {
        0.25
    }

    fn validate_y(&self, y: &[f64]) {
        for (i, &v) in y.iter().enumerate() {
            assert!(
                v.is_finite() && (0.0..=1.0).contains(&v),
                "logistic labels must lie in [0, 1]; y[{i}] = {v}"
            );
        }
    }

    fn zero_residual<'a>(&self, y: &'a [f64]) -> Cow<'a, [f64]> {
        Cow::Owned(y.iter().map(|v| v - 0.5).collect())
    }

    fn gap_scale(&self, y: &[f64]) -> f64 {
        y.len() as f64 * std::f64::consts::LN_2
    }

    fn loss(&self, y: &[f64], main: &[f64], _beta: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (&yi, &xb) in y.iter().zip(main) {
            acc += softplus(xb) - yi * xb;
        }
        acc
    }

    fn dual_at(&self, y: &[f64], theta: &[f64], _theta_aug_sq: f64, lambda: f64) -> f64 {
        // D(θ) = −Σ negent(y − λθ); clamp guards rounding at the domain
        // boundary (the scaling keeps y − λθ a convex combination of
        // values in [0, 1], so any excursion is pure float noise).
        let mut acc = 0.0;
        for (&yi, &ti) in y.iter().zip(theta) {
            acc += negent((yi - lambda * ti).clamp(0.0, 1.0));
        }
        -acc
    }

    fn adjust_xt<'a>(&self, xt: &'a [f64], _beta: &'a [f64]) -> Cow<'a, [f64]> {
        Cow::Borrowed(xt)
    }

    fn delta_sign(&self) -> f64 {
        1.0
    }

    fn sync_residual(&self, y: &[f64], state: &mut FitState) {
        let aux = state.aux.as_mut().expect("logistic FitState carries aux");
        for ((a, &yi), &xb) in aux.iter_mut().zip(y).zip(&state.main) {
            *a = yi - sigmoid(xb);
        }
    }

    fn supports_parallel_cd(&self) -> bool {
        false
    }

    fn init_state<D: Design>(&self, x: &D, y: &[f64], beta: &[f64]) -> FitState {
        let mut main = vec![0.0; y.len()];
        if beta.iter().any(|&b| b != 0.0) {
            x.matvec_into(beta, &mut main);
        }
        let mut state = FitState { main, aux: Some(vec![0.0; y.len()]) };
        self.sync_residual(y, &mut state);
        state
    }
}

/// Multi-response least squares `f(B) = ½‖Y − XB‖_F²` over `q` tasks
/// (arXiv 1506.03736). The maintained state is the residual matrix
/// `R = Y − XB`, flattened task-major; coefficients and correlations are
/// flattened feature-major (see the [module docs](self)).
///
/// Every scalar hook is implemented with the *same arithmetic* as the
/// plain [`Quadratic`] datafit on the flattened vectors (Frobenius = flat
/// ℓ2), so `MultiTaskQuadratic { tasks: 1 }` runs bit-identically to
/// `Quadratic { ridge: 0.0 }` — the safety contract
/// `tests/datafit_multitask.rs` pins across backends and solvers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MultiTaskQuadratic {
    /// Response width `q ≥ 1`.
    pub tasks: usize,
}

impl MultiTaskQuadratic {
    pub fn new(tasks: usize) -> MultiTaskQuadratic {
        assert!(tasks >= 1, "a multi-task datafit needs at least one task");
        MultiTaskQuadratic { tasks }
    }
}

impl Datafit for MultiTaskQuadratic {
    fn kind(&self) -> FitKind {
        FitKind::MultiTask
    }

    fn state_is_residual(&self) -> bool {
        true
    }

    fn tasks(&self) -> usize {
        self.tasks
    }

    fn zero_residual<'a>(&self, y: &'a [f64]) -> Cow<'a, [f64]> {
        Cow::Borrowed(y)
    }

    fn gap_scale(&self, y: &[f64]) -> f64 {
        // ‖Y‖_F² — the flat ℓ2 of the task-major layout, so q = 1 is the
        // scalar quadratic's ‖y‖² exactly.
        l2_norm_sq(y)
    }

    fn loss(&self, _y: &[f64], main: &[f64], _beta: &[f64]) -> f64 {
        0.5 * l2_norm_sq(main)
    }

    fn dual_at(&self, y: &[f64], theta: &[f64], _theta_aug_sq: f64, lambda: f64) -> f64 {
        // The multi-task dual objective is the scalar quadratic one on the
        // flattened (Frobenius) geometry.
        crate::solver::duality::dual_value(y, theta, lambda)
    }

    fn adjust_xt<'a>(&self, xt: &'a [f64], _beta: &'a [f64]) -> Cow<'a, [f64]> {
        Cow::Borrowed(xt)
    }

    fn delta_sign(&self) -> f64 {
        -1.0
    }

    fn sync_residual(&self, _y: &[f64], _state: &mut FitState) {}

    fn supports_parallel_cd(&self) -> bool {
        // The speculative parallel CD epoch proposes scalar per-feature
        // blocks; only the q = 1 degenerate case matches its indexing
        // (where this datafit *is* the plain quadratic, bit for bit).
        self.tasks == 1
    }

    fn init_state<D: Design>(&self, x: &D, y: &[f64], beta: &[f64]) -> FitState {
        let mut main = y.to_vec();
        if beta.iter().any(|&b| b != 0.0) {
            if self.tasks == 1 {
                // The scalar warm-start path, bit for bit.
                let xb = x.matvec(beta);
                for (r, v) in main.iter_mut().zip(&xb) {
                    *r -= v;
                }
            } else {
                let n = x.n_rows();
                let p = x.n_cols();
                let q = self.tasks;
                let mut beta_t = vec![0.0; p];
                let mut xb = vec![0.0; n];
                for t in 0..q {
                    for j in 0..p {
                        beta_t[j] = beta[j * q + t];
                    }
                    x.matvec_into(&beta_t, &mut xb);
                    for (r, v) in main[t * n..(t + 1) * n].iter_mut().zip(&xb) {
                        *r -= v;
                    }
                }
            }
        }
        FitState { main, aux: None }
    }
}

/// Numerically stable `σ(z) = 1/(1+e^{−z})` (no overflow for any finite
/// `z`; exact 0/1 saturation only in the far tails where `e^{∓z}`
/// underflows).
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        let e = (-z).exp();
        1.0 / (1.0 + e)
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `log(1 + e^z)`.
#[inline]
pub fn softplus(z: f64) -> f64 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Negative entropy `v·ln v + (1−v)·ln(1−v)` with the `0·ln 0 = 0`
/// convention; the (negated) logistic conjugate term. `ln(1−v)` is
/// evaluated as `ln_1p(−v)` for accuracy near `v = 0`.
#[inline]
pub fn negent(v: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&v));
    let a = if v > 0.0 { v * v.ln() } else { 0.0 };
    let b = if v < 1.0 { (1.0 - v) * (-v).ln_1p() } else { 0.0 };
    a + b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn fit_kind_names_round_trip() {
        for &k in FitKind::all() {
            assert_eq!(FitKind::from_name(k.name()), Some(k));
        }
        assert_eq!(FitKind::from_name("huber"), None);
    }

    #[test]
    fn sigmoid_and_softplus_are_stable_and_consistent() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(800.0) <= 1.0 && sigmoid(800.0) > 1.0 - 1e-12);
        assert!(sigmoid(-800.0) >= 0.0 && sigmoid(-800.0) < 1e-12);
        assert!(softplus(-800.0) >= 0.0 && softplus(-800.0) < 1e-12);
        assert!((softplus(800.0) - 800.0).abs() < 1e-9);
        for &z in &[-30.0, -2.5, -1e-8, 0.0, 1e-8, 2.5, 30.0] {
            // d/dz softplus = sigmoid (finite-difference check).
            let h = 1e-6;
            let fd = (softplus(z + h) - softplus(z - h)) / (2.0 * h);
            assert!((fd - sigmoid(z)).abs() < 1e-6, "z={z}: {fd} vs {}", sigmoid(z));
            // softplus(z) - z = softplus(-z) identity.
            assert!((softplus(z) - z - softplus(-z)).abs() < 1e-12);
        }
    }

    #[test]
    fn negent_boundary_convention() {
        assert_eq!(negent(0.0), 0.0);
        assert_eq!(negent(1.0), 0.0);
        assert!((negent(0.5) + std::f64::consts::LN_2).abs() < 1e-15);
        // Symmetric, minimized at 1/2.
        assert!((negent(0.2) - negent(0.8)).abs() < 1e-14);
        assert!(negent(0.2) > negent(0.5));
    }

    #[test]
    fn quadratic_init_state_is_warm_residual() {
        let x = Matrix::from_row_major(&[1.0, 0.0, 0.0, 2.0], 2, 2);
        let y = [1.0, 3.0];
        let q = Quadratic::default();
        let cold = q.init_state(&x, &y, &[0.0, 0.0]);
        assert_eq!(cold.main, vec![1.0, 3.0]);
        assert!(cold.aux.is_none());
        assert_eq!(cold.residual(), &[1.0, 3.0]);
        let warm = q.init_state(&x, &y, &[1.0, 0.5]);
        assert_eq!(warm.main, vec![0.0, 2.0]);
    }

    #[test]
    fn quadratic_ridge_adjustments_gate_cleanly() {
        let plain = Quadratic::default();
        let xt = [3.0, -1.0];
        let beta = [2.0, 4.0];
        assert!(matches!(plain.adjust_xt(&xt, &beta), Cow::Borrowed(_)));
        assert_eq!(plain.grad_correction(3.0, 2.0), 3.0);
        assert_eq!(plain.theta_aug_sq(&beta, 2.0), 0.0);
        assert!(plain.supports_parallel_cd());

        let en = Quadratic::with_ridge(0.5);
        let adj = en.adjust_xt(&xt, &beta);
        assert_eq!(adj.as_ref(), &[3.0 - 1.0, -1.0 - 2.0]);
        assert_eq!(en.grad_correction(3.0, 2.0), 2.0);
        // ‖−√μ β / s‖² = μ‖β‖²/s².
        assert!((en.theta_aug_sq(&beta, 2.0) - 0.5 * 20.0 / 4.0).abs() < 1e-15);
        assert!(!en.supports_parallel_cd());
    }

    #[test]
    fn logistic_state_and_residual() {
        let x = Matrix::from_row_major(&[1.0, 0.0, 0.0, -1.0], 2, 2);
        let y = [1.0, 0.0];
        let lg = Logistic;
        let st = lg.init_state(&x, &y, &[0.0, 0.0]);
        assert_eq!(st.main, vec![0.0, 0.0]);
        let r = st.residual();
        assert!((r[0] - 0.5).abs() < 1e-15 && (r[1] + 0.5).abs() < 1e-15);

        let warm = lg.init_state(&x, &y, &[2.0, 0.0]);
        assert_eq!(warm.main, vec![2.0, 0.0]);
        assert!((warm.residual()[0] - (1.0 - sigmoid(2.0))).abs() < 1e-15);
    }

    #[test]
    fn logistic_gap_closes_at_lambda_max_point() {
        // At β = 0 the dual point θ = (y − ½)/λ_max satisfies
        // y − λ_max·θ = ½ everywhere, so D(θ) = n·ln2 = P(0): zero gap.
        let y = [1.0, 0.0, 1.0, 1.0];
        let lg = Logistic;
        let zero = lg.zero_residual(&y);
        assert_eq!(zero.as_ref(), &[0.5, -0.5, 0.5, 0.5]);
        let lambda_max = 2.0; // stand-in scale; any λ with θ = r/λ works
        let theta: Vec<f64> = zero.iter().map(|v| v / lambda_max).collect();
        let d = lg.dual_at(&y, &theta, 0.0, lambda_max);
        let p0 = lg.loss(&y, &[0.0; 4], &[]);
        assert!((p0 - 4.0 * std::f64::consts::LN_2).abs() < 1e-14);
        assert!((d - p0).abs() < 1e-14, "dual {d} vs primal {p0}");
        assert!((lg.gap_scale(&y) - p0).abs() < 1e-14);
    }

    #[test]
    #[should_panic(expected = "logistic labels")]
    fn logistic_rejects_out_of_range_labels() {
        Logistic.validate_y(&[0.0, 1.5]);
    }

    #[test]
    fn multitask_q1_state_matches_quadratic_bitwise() {
        let x = Matrix::from_row_major(&[1.0, 0.0, 0.0, 2.0], 2, 2);
        let y = [1.0, 3.0];
        let q = Quadratic::default();
        let mt = MultiTaskQuadratic::new(1);
        for beta in [[0.0, 0.0], [1.0, 0.5]] {
            let a = q.init_state(&x, &y, &beta);
            let b = mt.init_state(&x, &y, &beta);
            assert_eq!(a.main, b.main);
            assert!(b.aux.is_none());
        }
        assert_eq!(q.gap_scale(&y).to_bits(), mt.gap_scale(&y).to_bits());
        assert!(mt.supports_parallel_cd());
        assert!(!MultiTaskQuadratic::new(2).supports_parallel_cd());
        assert_eq!(mt.tasks(), 1);
        assert_eq!(FitKind::from_name("multitask"), Some(FitKind::MultiTask));
    }

    #[test]
    fn multitask_warm_state_is_per_task_residual() {
        // X is 2x2; two tasks. beta is feature-major: rows (1, -1), (0, 2).
        let x = Matrix::from_row_major(&[1.0, 0.0, 0.0, 2.0], 2, 2);
        let y = [1.0, 3.0, 5.0, 7.0]; // task-major: Y_0 = (1,3), Y_1 = (5,7)
        let mt = MultiTaskQuadratic::new(2);
        let beta = [1.0, -1.0, 0.0, 2.0];
        let st = mt.init_state(&x, &y, &beta);
        // Task 0 uses beta column (1, 0): Xb = (1, 0); task 1 uses
        // (-1, 2): Xb = (-1, 4).
        assert_eq!(st.main, vec![0.0, 3.0, 6.0, 3.0]);
        assert_eq!(st.residual().len(), 4);
    }

    #[test]
    #[should_panic(expected = "at least one task")]
    fn multitask_rejects_zero_tasks() {
        MultiTaskQuadratic::new(0);
    }
}
