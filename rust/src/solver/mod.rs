//! The Sparse-Group Lasso solver stack:
//!
//! - [`groups`] — feature partitions;
//! - [`problem`] — problem instances + precomputations + `λ_max` (Eq. 22),
//!   generic over the [`crate::linalg::Design`] backend (dense or CSC)
//!   and the [`datafit`] (least squares or logistic);
//! - [`datafit`] — the smooth-loss abstraction: residual/state
//!   maintenance, loss/dual evaluation, and the screening-safety
//!   constants (dual scaling, curvature);
//! - [`duality`] — primal/dual objectives, dual scaling (Eq. 15), GAP
//!   radius (Thm. 2);
//! - [`active_set`] — the shared active-set core: backend-generic column
//!   compaction, gap-check/screening plumbing, terminal-dual handoff;
//! - [`cd`] — ISTA-BC block coordinate descent (Algorithm 2);
//! - [`ista`] — full proximal-gradient (mirrors the XLA artifact);
//! - [`fista`] — accelerated variant with screening/function restarts;
//! - [`sweep`] — the intra-path parallel execution layer: work-stealing
//!   per-check kernels, bit-identical parallel ISTA/FISTA sweeps, and the
//!   bulk-synchronous parallel CD epoch (`sweep = "parallel"`);
//! - [`path`] — warm-started λ-path (§7.1), solver-selectable;
//! - [`cv`] — `(λ, τ)` grid validation (Fig. 3a);
//! - [`elastic_net`] — App. D reformulation;
//! - [`strong`] — the *unsafe* sequential strong rules baseline with KKT
//!   recovery (the contrast the paper draws in §1/§7).

pub mod active_set;
pub mod cd;
pub mod cv;
pub mod datafit;
pub mod duality;
pub mod elastic_net;
pub mod fista;
pub mod groups;
pub mod ista;
pub mod path;
pub mod problem;
pub mod strong;
pub mod sweep;

/// Which native solver runs a single-λ solve. All three are generic over
/// the design backend and drive the shared [`active_set`] core, so the
/// screening rules (including the sequential carry of `GapSafeSeq`)
/// behave identically across them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Block coordinate descent (paper Algorithm 2) — the default.
    Cd,
    /// Full proximal gradient.
    Ista,
    /// Accelerated proximal gradient with restarts.
    Fista,
}

impl SolverKind {
    pub fn name(self) -> &'static str {
        match self {
            SolverKind::Cd => "cd",
            SolverKind::Ista => "ista",
            SolverKind::Fista => "fista",
        }
    }

    pub fn all() -> [SolverKind; 3] {
        [SolverKind::Cd, SolverKind::Ista, SolverKind::Fista]
    }

    pub fn from_name(s: &str) -> Option<SolverKind> {
        Self::all().into_iter().find(|k| k.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::SolverKind;

    #[test]
    fn solver_kind_round_trip() {
        for k in SolverKind::all() {
            assert_eq!(SolverKind::from_name(k.name()), Some(k));
        }
        assert_eq!(SolverKind::from_name("bogus"), None);
    }
}
