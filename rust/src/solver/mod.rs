//! The Sparse-Group Lasso solver stack:
//!
//! - [`groups`] — feature partitions;
//! - [`problem`] — problem instances + precomputations + `λ_max` (Eq. 22);
//! - [`duality`] — primal/dual objectives, dual scaling (Eq. 15), GAP
//!   radius (Thm. 2);
//! - [`cd`] — ISTA-BC block coordinate descent (Algorithm 2);
//! - [`ista`] — masked full proximal-gradient (mirrors the XLA artifact);
//! - [`fista`] — accelerated variant with screening/function restarts;
//! - [`path`] — warm-started λ-path (§7.1);
//! - [`cv`] — `(λ, τ)` grid validation (Fig. 3a);
//! - [`elastic_net`] — App. D reformulation;
//! - [`strong`] — the *unsafe* sequential strong rules baseline with KKT
//!   recovery (the contrast the paper draws in §1/§7).

pub mod cd;
pub mod cv;
pub mod duality;
pub mod elastic_net;
pub mod fista;
pub mod groups;
pub mod ista;
pub mod path;
pub mod problem;
pub mod strong;
