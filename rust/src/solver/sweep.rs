//! Intra-path parallel execution layer: work-stealing sweeps *inside* a
//! single solve.
//!
//! `PathBatch` (PR 1) parallelizes across paths and the solve service
//! (PR 3) across λ-shards; within one path every per-group sweep was
//! still serial — the single-path latency axis. This module closes it,
//! parallelizing over the compact `(g, start, end)` ranges of
//! [`ActiveCols`] on a per-solve [`WorkCrew`]:
//!
//! - **per-check work** — the full `Xᵀρ` of a gap evaluation
//!   ([`xt_full`]), the compacted correlation sweep ([`xt_active`]), the
//!   per-group dual norm ([`omega_dual`]) and the screening decision pass
//!   ([`crate::screening::apply_sphere_ctx`]) are embarrassingly parallel
//!   per column/group with disjoint writes, so their parallel versions
//!   are **bit-identical** to the serial ones;
//! - **full-gradient sweeps** — ISTA/FISTA prox steps are Jacobi by
//!   construction (every group update reads the same `Xᵀρ`), so
//!   [`ista_sweep`]/[`fista_sweep`] parallelize them without changing a
//!   single bit, and the row-partitioned [`residual`] keeps each row's
//!   accumulation in serial column order (also bit-identical);
//! - **parallel CD epochs** — coordinate descent is inherently
//!   sequential, so [`cd_epoch_parallel`] switches the epoch to
//!   bulk-synchronous rounds: each worker proposes block updates against
//!   the round-start residual, a barrier, then the deltas are reduced
//!   into `ρ` over row partitions. Rounds take *strided* group subsets
//!   (adjacent groups are the correlated ones on banded designs), and
//!   each round updates only `threads ·`[`GROUPS_PER_ROUND_PER_WORKER`]
//!   groups simultaneously, keeping the Jacobi degree small enough that
//!   the MM majorization still dominates the cross-block coupling. The
//!   iterates differ from the cyclic sweep (same optimum, different
//!   trajectory), which is why the CD mode is opt-in
//!   (`sweep = "parallel"`) and falls back to the serial cyclic sweep
//!   when the active set is small ([`SweepCtx::engage`]).
//!
//! Everything is gated on [`SolveOptions::sweep`]: the default
//! `SweepMode::Serial` spawns no threads and leaves every solver
//! bit-for-bit unchanged.

use super::active_set::ActiveCols;
use super::cd::SolveOptions;
use super::datafit::{Datafit, FitState};
use super::problem::SglProblem;
use crate::linalg::Design;
use crate::norms::block::sgl_prox_rows_inplace;
use crate::norms::prox::sgl_prox_inplace;
use crate::norms::sgl::{omega_dual as omega_dual_serial, omega_dual_group};
use crate::solver::groups::Groups;
use crate::util::pool::{
    even_chunk, resolve_threads, SharedSlice, SpinBarrier, WorkCrew, WorkQueue,
};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// How one epoch sweeps the active groups (`[solver] sweep` in TOML,
/// `--sweep` on the CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepMode {
    /// The classic cyclic sweep (paper Algorithm 2); single-threaded
    /// within a solve. The default.
    Serial,
    /// Work-stealing parallel sweeps over the active-set group ranges:
    /// bit-identical for ISTA/FISTA, bulk-synchronous Jacobi rounds for
    /// CD (same optimum, different trajectory).
    Parallel,
}

impl SweepMode {
    pub fn name(self) -> &'static str {
        match self {
            SweepMode::Serial => "serial",
            SweepMode::Parallel => "parallel",
        }
    }

    pub fn all() -> [SweepMode; 2] {
        [SweepMode::Serial, SweepMode::Parallel]
    }

    pub fn from_name(s: &str) -> Option<SweepMode> {
        Self::all().into_iter().find(|m| m.name() == s)
    }
}

/// Tunable floors for the parallel sweep kernels, carried in
/// [`SolveOptions`] and exposed as `[solver]` config knobs. Every field
/// defaults to the constant the kernels shipped with; the floors decide
/// *when* a kernel takes its parallel branch (never *what* it computes —
/// gap/screening/prox kernels are bit-identical either way, and the CD
/// epoch keeps its monotonicity guard), except that `cd_floor` and
/// `groups_per_round` also shape the parallel-CD trajectory, which is why
/// the tuning travels with the solve options through the wire codec and the
/// service cache key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SweepTuning {
    /// Per-worker column floor for the `xt_full`/`xt_active` sweeps.
    pub xt_floor: usize,
    /// Per-worker active-column floor for the row-partitioned residual /
    /// linear-predictor kernels.
    pub residual_floor: usize,
    /// Per-worker group floor for the parallel `Ω^D` dual-norm sweep.
    pub omega_dual_floor: usize,
    /// Per-worker group floor for the ISTA/FISTA prox sweeps.
    pub prox_floor: usize,
    /// Per-worker group floor below which the CD epoch falls back to the
    /// serial cyclic sweep.
    pub cd_floor: usize,
    /// Block updates proposed simultaneously per round and worker in
    /// [`cd_epoch_parallel`] (see [`GROUPS_PER_ROUND_PER_WORKER`]).
    pub groups_per_round: usize,
}

impl Default for SweepTuning {
    fn default() -> Self {
        SweepTuning {
            xt_floor: 64,
            residual_floor: 64,
            omega_dual_floor: 32,
            prox_floor: 16,
            cd_floor: 8,
            groups_per_round: GROUPS_PER_ROUND_PER_WORKER,
        }
    }
}

std::thread_local! {
    /// Parked crew from the previous parallel solve on this OS thread. A
    /// warm-started path runs hundreds of short solves back to back;
    /// recycling the crew turns "spawn + join `threads−1` OS threads per
    /// λ" into "once per owning thread" ([`SweepCtx::drop`] parks it,
    /// [`SweepCtx::from_opts`] picks it back up when the size matches).
    static PARKED_CREW: std::cell::RefCell<Option<WorkCrew>> =
        const { std::cell::RefCell::new(None) };
}

/// Per-solve sweep context: `None` crew = serial. Holds the worker crew
/// for the solve's lifetime (created by `ScreenState::new`, parked again
/// when the solve ends), so per-epoch parallel regions pay a condvar
/// broadcast, not a thread spawn.
pub struct SweepCtx {
    crew: Option<WorkCrew>,
    /// Engage floors / round sizing for the kernels driven by this context.
    pub tuning: SweepTuning,
}

impl SweepCtx {
    /// Serial context: every kernel takes its single-threaded branch.
    pub fn serial() -> SweepCtx {
        SweepCtx { crew: None, tuning: SweepTuning::default() }
    }

    /// Build from the solve options: a crew only for
    /// `sweep = "parallel"` with an effective thread count ≥ 2
    /// (`sweep_threads = 0` means auto, like every other thread knob) —
    /// recycled from this thread's parked crew when the size matches,
    /// freshly spawned otherwise.
    pub fn from_opts(opts: &SolveOptions) -> SweepCtx {
        match opts.sweep {
            SweepMode::Serial => SweepCtx { crew: None, tuning: opts.tuning },
            SweepMode::Parallel => {
                let threads = resolve_threads(opts.sweep_threads);
                if threads >= 2 {
                    let crew = PARKED_CREW.with(|slot| {
                        match slot.borrow_mut().take() {
                            Some(c) if c.threads() == threads => c,
                            // A differently-sized leftover is dropped
                            // (joins its helpers) and replaced.
                            _ => WorkCrew::new(threads),
                        }
                    });
                    SweepCtx { crew: Some(crew), tuning: opts.tuning }
                } else {
                    SweepCtx { crew: None, tuning: opts.tuning }
                }
            }
        }
    }

    #[inline]
    pub fn is_parallel(&self) -> bool {
        self.crew.is_some()
    }

    /// Worker count (1 when serial).
    #[inline]
    pub fn threads(&self) -> usize {
        self.crew.as_ref().map_or(1, WorkCrew::threads)
    }

    /// Whether a parallel region over `units` work items is worth its
    /// dispatch cost: parallel mode is on *and* every worker would get at
    /// least `per_worker` items. Kernels below are bit-identical either
    /// way; for the CD epoch this is also the "active set is small →
    /// serial cyclic fallback" switch.
    #[inline]
    pub fn engage(&self, units: usize, per_worker: usize) -> bool {
        match &self.crew {
            Some(crew) => units >= per_worker * crew.threads(),
            None => false,
        }
    }

    fn crew_if(&self, units: usize, per_worker: usize) -> Option<&WorkCrew> {
        if self.engage(units, per_worker) {
            self.crew.as_ref()
        } else {
            None
        }
    }

    /// `f(i)` for every `i in 0..n`, work-stealing `chunk`-sized ranges
    /// when the region engages (`n ≥ per_worker · threads`), plain loop
    /// otherwise. Callers whose `f` writes shared memory must write
    /// disjoint locations per `i`.
    pub fn for_each<F>(&self, n: usize, chunk: usize, per_worker: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        match self.crew_if(n, per_worker) {
            Some(crew) => {
                let queue = WorkQueue::new(n, chunk);
                crew.run(&|_w| {
                    while let Some((a, b)) = queue.next() {
                        for i in a..b {
                            f(i);
                        }
                    }
                });
            }
            None => {
                for i in 0..n {
                    f(i);
                }
            }
        }
    }
}

impl Drop for SweepCtx {
    fn drop(&mut self) {
        if let Some(crew) = self.crew.take() {
            // Park for the next solve on this thread; a previously parked
            // crew (if any) is dropped and joined here. `try_with` covers
            // drops racing thread-local teardown — the crew then just
            // drops (joining its helpers) instead of parking.
            let _ = PARKED_CREW.try_with(|slot| *slot.borrow_mut() = Some(crew));
        }
    }
}

/// Full correlation vector `xt = Xᵀv` over **all** columns (gap checks
/// need every feature, screened or not). Each column is an independent
/// dot product with a disjoint write: bit-identical to the serial
/// `tmatvec_into` under any schedule.
pub fn xt_full<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    pb: &SglProblem<D, F>,
    v: &[f64],
    xt: &mut [f64],
) {
    let p = pb.p();
    let q = pb.datafit.tasks();
    debug_assert_eq!(xt.len(), p * q);
    if q > 1 {
        // Multi-response: `v` is the task-major n × q state, `xt` the
        // feature-major p × q correlation matrix. Columns still have
        // disjoint writes, so the parallel schedule stays deterministic.
        let n = pb.n();
        let out = SharedSlice::new(xt);
        ctx.for_each(p, 64, ctx.tuning.xt_floor, |j| {
            for t in 0..q {
                // SAFETY: each column index is claimed by exactly one
                // worker; (j, t) writes are disjoint.
                unsafe { out.set(j * q + t, pb.x.col_dot(j, &v[t * n..(t + 1) * n])) };
            }
        });
        return;
    }
    if !ctx.engage(p, ctx.tuning.xt_floor) {
        pb.x.tmatvec_into(v, xt);
        return;
    }
    let out = SharedSlice::new(xt);
    ctx.for_each(p, 64, ctx.tuning.xt_floor, |j| {
        // SAFETY: each column index is claimed by exactly one worker.
        unsafe { out.set(j, pb.x.col_dot(j, v)) };
    });
}

/// `xt[j] = X_jᵀv` for the active features only, streaming the packed
/// columns (screened entries left untouched, exactly like
/// [`ActiveCols::xt_into`]). Bit-identical to the serial sweep.
pub fn xt_active<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    cols: &ActiveCols<D>,
    pb: &SglProblem<D, F>,
    v: &[f64],
    xt: &mut [f64],
) {
    let n_active = cols.n_active();
    let q = pb.datafit.tasks();
    if q > 1 {
        let n = pb.n();
        let out = SharedSlice::new(xt);
        ctx.for_each(n_active, 64, ctx.tuning.xt_floor, |k| {
            let j = cols.feature(k);
            for t in 0..q {
                // SAFETY: compact columns map to distinct original
                // features; (j, t) writes are disjoint.
                unsafe { out.set(j * q + t, cols.col_dot(pb, k, &v[t * n..(t + 1) * n])) };
            }
        });
        return;
    }
    if !ctx.engage(n_active, ctx.tuning.xt_floor) {
        cols.xt_into(pb, v, xt);
        return;
    }
    let out = SharedSlice::new(xt);
    ctx.for_each(n_active, 64, ctx.tuning.xt_floor, |k| {
        // SAFETY: compact columns map to distinct original features.
        unsafe { out.set(cols.feature(k), cols.col_dot(pb, k, v)) };
    });
}

/// `ρ = y − Xβ` over the active columns, row-partitioned: worker `w` owns
/// the row range [`even_chunk`]`(n, threads, w)` and accumulates every
/// column's contribution to it in column order — the same per-row
/// addition order as the serial [`ActiveCols::residual_into`], hence
/// bit-identical results.
pub fn residual<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    cols: &ActiveCols<D>,
    pb: &SglProblem<D, F>,
    beta: &[f64],
    rho: &mut [f64],
) {
    let n_active = cols.n_active();
    let q = pb.datafit.tasks();
    if q > 1 {
        // Multi-response residual, task by task: R_t = Y_t − X B_t over
        // the active columns, serial column order (deterministic).
        let n = pb.n();
        for t in 0..q {
            let rt = &mut rho[t * n..(t + 1) * n];
            rt.copy_from_slice(&pb.y[t * n..(t + 1) * n]);
            for k in 0..n_active {
                let bj = beta[cols.feature(k) * q + t];
                if bj != 0.0 {
                    cols.col_axpy(pb, k, -bj, rt);
                }
            }
        }
        return;
    }
    let crew = match ctx.crew_if(n_active, ctx.tuning.residual_floor) {
        Some(c) => c,
        None => {
            cols.residual_into(pb, beta, rho);
            return;
        }
    };
    let n = pb.n();
    let threads = crew.threads();
    let out = SharedSlice::new(rho);
    crew.run(&|w| {
        let (row0, row1) = even_chunk(n, threads, w);
        if row0 >= row1 {
            return;
        }
        // SAFETY: row ranges are disjoint across workers.
        let mine = unsafe { out.range_mut(row0, row1) };
        mine.copy_from_slice(&pb.y[row0..row1]);
        for k in 0..n_active {
            let bj = beta[cols.feature(k)];
            if bj != 0.0 {
                cols.col_axpy_rows(pb, k, -bj, row0, row1, mine);
            }
        }
    });
}

/// `xb = Xβ` over the active columns, row-partitioned exactly like
/// [`residual`] (same per-row accumulation order, hence bit-identical to
/// [`ActiveCols::linear_predictor_into`]).
pub fn linear_predictor<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    cols: &ActiveCols<D>,
    pb: &SglProblem<D, F>,
    beta: &[f64],
    xb: &mut [f64],
) {
    let n_active = cols.n_active();
    let q = pb.datafit.tasks();
    if q > 1 {
        let n = pb.n();
        for t in 0..q {
            let xbt = &mut xb[t * n..(t + 1) * n];
            xbt.fill(0.0);
            for k in 0..n_active {
                let bj = beta[cols.feature(k) * q + t];
                if bj != 0.0 {
                    cols.col_axpy(pb, k, bj, xbt);
                }
            }
        }
        return;
    }
    let crew = match ctx.crew_if(n_active, ctx.tuning.residual_floor) {
        Some(c) => c,
        None => {
            cols.linear_predictor_into(pb, beta, xb);
            return;
        }
    };
    let n = pb.n();
    let threads = crew.threads();
    let out = SharedSlice::new(xb);
    crew.run(&|w| {
        let (row0, row1) = even_chunk(n, threads, w);
        if row0 >= row1 {
            return;
        }
        // SAFETY: row ranges are disjoint across workers.
        let mine = unsafe { out.range_mut(row0, row1) };
        mine.fill(0.0);
        for k in 0..n_active {
            let bj = beta[cols.feature(k)];
            if bj != 0.0 {
                cols.col_axpy_rows(pb, k, bj, row0, row1, mine);
            }
        }
    });
}

/// Recompute the datafit state from scratch over the active columns: the
/// periodic drift-correction refresh every solver runs. Rebuilds
/// [`FitState::main`] with the kernel matching the datafit's state kind
/// (residual vs linear predictor) and re-syncs the derived residual.
pub fn refresh_state<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    cols: &ActiveCols<D>,
    pb: &SglProblem<D, F>,
    beta: &[f64],
    fit: &mut FitState,
) {
    if pb.datafit.state_is_residual() {
        residual(ctx, cols, pb, beta, &mut fit.main);
    } else {
        linear_predictor(ctx, cols, pb, beta, &mut fit.main);
    }
    pb.datafit.sync_residual(&pb.y, fit);
}

/// The SGL dual norm `Ω^D(ξ)`, its per-group ε-norms evaluated in
/// parallel. The combine is a `max` over the per-group values, so the
/// result is bit-identical to [`crate::norms::sgl::omega_dual`].
pub fn omega_dual(ctx: &SweepCtx, xi: &[f64], groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    let ng = groups.n_groups();
    if !ctx.engage(ng, ctx.tuning.omega_dual_floor) {
        return omega_dual_serial(xi, groups, tau, w);
    }
    let mut vals = vec![0.0f64; ng];
    {
        let out = SharedSlice::new(&mut vals);
        ctx.for_each(ng, 16, ctx.tuning.omega_dual_floor, |g| {
            let (a, b) = groups.bounds(g);
            // SAFETY: one group per worker.
            unsafe { out.set(g, omega_dual_group(&xi[a..b], tau, w[g])) };
        });
    }
    vals.into_iter().fold(0.0f64, f64::max)
}

/// Per-solve scratch for the prox sweeps: one `max_group`-wide block per
/// worker, allocated once (the serial branch uses worker 0's block), so
/// per-epoch sweeps never touch the allocator.
pub struct ProxScratch {
    buf: Vec<f64>,
    width: usize,
}

impl ProxScratch {
    /// `threads` blocks of `max_group` coefficients. Multi-response
    /// solvers pass `max_group · q` so a block holds a group's whole
    /// feature-major coefficient panel.
    pub fn new(max_group: usize, threads: usize) -> Self {
        let threads = threads.max(1);
        ProxScratch { buf: vec![0.0; max_group * threads], width: max_group }
    }
}

/// One ISTA prox sweep over the active groups:
/// `β_g ← prox(β_g + (Xᵀρ)_g / L)`. Every group reads the same `xt_rho`,
/// so groups are independent and the parallel branch is bit-identical to
/// the serial loop. Returns whether any coefficient changed.
#[allow(clippy::too_many_arguments)]
pub fn ista_sweep<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    cols: &ActiveCols<D>,
    pb: &SglProblem<D, F>,
    lambda: f64,
    l_global: f64,
    beta: &mut [f64],
    xt_rho: &[f64],
    scratch: &mut ProxScratch,
) -> bool {
    let groups = cols.groups();
    let width = scratch.width;
    let q = pb.datafit.tasks();
    if q > 1 {
        // Multi-response prox sweep (serial — the per-group row prox is
        // cheap relative to the correlation sweep): β rows are
        // feature-major, the group block gathers into a d × q panel and
        // runs the row-block SGL prox.
        let block = &mut scratch.buf[..width];
        let mut changed = false;
        for &(g, s, e) in groups {
            let d = e - s;
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                for t in 0..q {
                    block[k * q + t] = beta[j * q + t] + xt_rho[j * q + t] / l_global;
                }
            }
            sgl_prox_rows_inplace(
                &mut block[..d * q],
                q,
                pb.tau * lambda / l_global,
                (1.0 - pb.tau) * pb.weights[g] * lambda / l_global,
            );
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                for t in 0..q {
                    if block[k * q + t] != beta[j * q + t] {
                        beta[j * q + t] = block[k * q + t];
                        changed = true;
                    }
                }
            }
        }
        return changed;
    }
    if !ctx.engage(groups.len(), ctx.tuning.prox_floor) {
        let block = &mut scratch.buf[..width];
        let mut changed = false;
        for &(g, s, e) in groups {
            let d = e - s;
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                block[k] = beta[j] + xt_rho[j] / l_global;
            }
            sgl_prox_inplace(
                &mut block[..d],
                pb.tau * lambda / l_global,
                (1.0 - pb.tau) * pb.weights[g] * lambda / l_global,
            );
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                if block[k] != beta[j] {
                    beta[j] = block[k];
                    changed = true;
                }
            }
        }
        return changed;
    }
    let crew = ctx.crew.as_ref().expect("engage implies a crew");
    debug_assert!(scratch.buf.len() >= width * crew.threads());
    let changed = AtomicBool::new(false);
    let queue = WorkQueue::new(groups.len(), 4);
    let beta_sh = SharedSlice::new(beta);
    let blocks = SharedSlice::new(&mut scratch.buf);
    crew.run(&|w| {
        // SAFETY: per-worker block ranges are disjoint.
        let local = unsafe { blocks.range_mut(w * width, (w + 1) * width) };
        let mut any = false;
        while let Some((ga, gb)) = queue.next() {
            for &(g, s, e) in &groups[ga..gb] {
                let d = e - s;
                for (k, idx) in (s..e).enumerate() {
                    let j = cols.feature(idx);
                    // SAFETY: β reads/writes stay within this worker's
                    // claimed groups (disjoint feature ranges).
                    local[k] = unsafe { beta_sh.get(j) } + xt_rho[j] / l_global;
                }
                sgl_prox_inplace(
                    &mut local[..d],
                    pb.tau * lambda / l_global,
                    (1.0 - pb.tau) * pb.weights[g] * lambda / l_global,
                );
                for (k, idx) in (s..e).enumerate() {
                    let j = cols.feature(idx);
                    let old = unsafe { beta_sh.get(j) };
                    if local[k] != old {
                        unsafe { beta_sh.set(j, local[k]) };
                        any = true;
                    }
                }
            }
        }
        if any {
            changed.store(true, Ordering::Relaxed);
        }
    });
    changed.load(Ordering::Relaxed)
}

/// One FISTA gradient/prox sweep at the extrapolated point `z`:
/// `β⁺_g ← prox(z_g + (Xᵀρ)_g · L⁻¹)`, written into `beta_next`.
/// Bit-identical to the serial loop for the same reason as
/// [`ista_sweep`].
#[allow(clippy::too_many_arguments)]
pub fn fista_sweep<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    cols: &ActiveCols<D>,
    pb: &SglProblem<D, F>,
    lambda: f64,
    inv_l: f64,
    z: &[f64],
    xt_rho: &[f64],
    beta_next: &mut [f64],
    scratch: &mut ProxScratch,
) {
    let groups = cols.groups();
    let width = scratch.width;
    let q = pb.datafit.tasks();
    if q > 1 {
        let block = &mut scratch.buf[..width];
        for &(g, s, e) in groups {
            let d = e - s;
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                for t in 0..q {
                    block[k * q + t] = z[j * q + t] + xt_rho[j * q + t] * inv_l;
                }
            }
            sgl_prox_rows_inplace(
                &mut block[..d * q],
                q,
                pb.tau * lambda * inv_l,
                (1.0 - pb.tau) * pb.weights[g] * lambda * inv_l,
            );
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                beta_next[j * q..(j + 1) * q].copy_from_slice(&block[k * q..(k + 1) * q]);
            }
        }
        return;
    }
    if !ctx.engage(groups.len(), ctx.tuning.prox_floor) {
        let block = &mut scratch.buf[..width];
        for &(g, s, e) in groups {
            let d = e - s;
            for (k, idx) in (s..e).enumerate() {
                let j = cols.feature(idx);
                block[k] = z[j] + xt_rho[j] * inv_l;
            }
            sgl_prox_inplace(
                &mut block[..d],
                pb.tau * lambda * inv_l,
                (1.0 - pb.tau) * pb.weights[g] * lambda * inv_l,
            );
            for (k, idx) in (s..e).enumerate() {
                beta_next[cols.feature(idx)] = block[k];
            }
        }
        return;
    }
    let crew = ctx.crew.as_ref().expect("engage implies a crew");
    debug_assert!(scratch.buf.len() >= width * crew.threads());
    let queue = WorkQueue::new(groups.len(), 4);
    let next_sh = SharedSlice::new(beta_next);
    let blocks = SharedSlice::new(&mut scratch.buf);
    crew.run(&|w| {
        // SAFETY: per-worker block ranges are disjoint.
        let local = unsafe { blocks.range_mut(w * width, (w + 1) * width) };
        while let Some((ga, gb)) = queue.next() {
            for &(g, s, e) in &groups[ga..gb] {
                let d = e - s;
                for (k, idx) in (s..e).enumerate() {
                    let j = cols.feature(idx);
                    local[k] = z[j] + xt_rho[j] * inv_l;
                }
                sgl_prox_inplace(
                    &mut local[..d],
                    pb.tau * lambda * inv_l,
                    (1.0 - pb.tau) * pb.weights[g] * lambda * inv_l,
                );
                for (k, idx) in (s..e).enumerate() {
                    // SAFETY: groups write disjoint feature ranges.
                    unsafe { next_sh.set(cols.feature(idx), local[k]) };
                }
            }
        }
    });
}

/// Block updates proposed simultaneously per round, per worker. Small
/// enough that the per-block MM majorization usually dominates the
/// cross-block coupling (rounds are strided, so simultaneous blocks are
/// far apart and near-uncorrelated on banded designs); large enough to
/// amortize the barrier crossings per round. The monotonicity guard in
/// [`cd_epoch_parallel`] makes the choice a performance knob, never a
/// correctness one.
pub const GROUPS_PER_ROUND_PER_WORKER: usize = 4;

/// Reusable buffers for [`cd_epoch_parallel`], allocated once per solve.
pub struct CdParScratch {
    /// Proposed coefficient per compact column.
    proposed: Vec<f64>,
    /// Proposed − current coefficient per compact column.
    delta: Vec<f64>,
    /// Per-worker `Σ ρ_i²` over its row slice (acceptance test input).
    rho_sq_partial: Vec<f64>,
    barrier: SpinBarrier,
}

impl CdParScratch {
    pub fn new(p: usize, threads: usize) -> Self {
        CdParScratch {
            proposed: vec![0.0; p],
            delta: vec![0.0; p],
            rho_sq_partial: vec![0.0; threads],
            barrier: SpinBarrier::new(threads),
        }
    }
}

/// `τ‖β_g‖₁ + (1−τ)w_g‖β_g‖` summed over the round's groups, reading
/// coefficients by compact column through an accessor (old β before the
/// commit, proposals after).
fn round_omega<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    round_groups: impl Iterator<Item = (usize, usize, usize)>,
    coef: impl Fn(usize) -> f64,
) -> f64 {
    let mut total = 0.0;
    for (g, s, e) in round_groups {
        let mut l1 = 0.0;
        let mut l2_sq = 0.0;
        for k in s..e {
            let v = coef(k);
            l1 += v.abs();
            l2_sq += v * v;
        }
        total += pb.tau * l1 + (1.0 - pb.tau) * pb.weights[g] * l2_sq.sqrt();
    }
    total
}

/// One bulk-synchronous parallel CD epoch over the compacted active
/// groups.
///
/// Groups are split into strided round subsets (round `r` takes group
/// indices `r, r + n_rounds, …` — adjacent groups, the correlated ones on
/// banded designs, land in *different* rounds). Each round:
///
/// 1. **propose** — workers steal groups and compute the MM block update
///    `β_g ← prox(β_g + X_gᵀρ / L_g)` against the round-start residual,
///    recording proposal and delta per compact column (disjoint writes);
/// 2. barrier;
/// 3. **apply** — the deltas are reduced into `ρ` over a static row
///    partition (each worker owns a row range and also accumulates its
///    slice's `Σρ²`; per-row addition order is the round's column order,
///    so the reduction is deterministic), while worker 0 commits the
///    coefficients and the round's penalty terms;
/// 4. barrier; **accept test** — worker 0 evaluates the round's primal
///    change `½Δ‖ρ‖² + λΔΩ`. Simultaneous block-MM steps are descent
///    steps *unless* the cross-block coupling overwhelms the per-block
///    curvature (the Shotgun divergence regime — possible when many
///    correlated blocks move at once). An increasing round is **reverted
///    and redone sequentially** by worker 0 (exact Gauss–Seidel, which
///    always descends), so the epoch is monotone by construction: the
///    round size is a performance knob, never a correctness one. On the
///    strided subsets the coupling is zero-mean and `O(1/√n)` relative
///    to the curvature, so reverts are rare;
/// 5. barrier, next round.
///
/// Callers gate this on [`SweepCtx::engage`] so every round updates at
/// most half the active groups.
pub fn cd_epoch_parallel<D: Design, F: Datafit>(
    ctx: &SweepCtx,
    scratch: &mut CdParScratch,
    pb: &SglProblem<D, F>,
    cols: &ActiveCols<D>,
    lambda: f64,
    beta: &mut [f64],
    rho: &mut [f64],
) {
    // The bulk-synchronous accept test below prices a round by ½Δ‖ρ‖²,
    // which is the loss change only for the plain quadratic datafit.
    debug_assert!(pb.datafit.supports_parallel_cd());
    let crew = ctx.crew.as_ref().expect("parallel epoch requires a crew");
    let threads = crew.threads();
    debug_assert_eq!(scratch.barrier.participants(), threads);
    debug_assert_eq!(scratch.rho_sq_partial.len(), threads);
    let groups = cols.groups();
    let n = pb.n();
    let round = threads * ctx.tuning.groups_per_round.max(1);
    let n_rounds = groups.len().div_ceil(round).max(1);
    // Per-round stealing cursors: cursor `r` walks the round's strided
    // member list `gi = r + t·n_rounds`.
    let cursors: Vec<AtomicUsize> = (0..n_rounds).map(|_| AtomicUsize::new(0)).collect();
    let members = |r: usize| (groups.len() - r).div_ceil(n_rounds);
    let max_group = groups.iter().map(|&(_, s, e)| e - s).max().unwrap_or(0);
    let proposed = SharedSlice::new(&mut scratch.proposed);
    let delta = SharedSlice::new(&mut scratch.delta);
    let partial = SharedSlice::new(&mut scratch.rho_sq_partial);
    let beta_sh = SharedSlice::new(beta);
    let rho_sh = SharedSlice::new(rho);
    let barrier = &scratch.barrier;
    let abort = crew.abort_flag();
    // Worker 0's accept verdict, broadcast to the crew between barriers.
    let accepted = AtomicBool::new(true);
    crew.run(&|w| {
        // Rolling `‖ρ‖²` — read and written by worker 0 only.
        let mut rho_sq_old = if w == 0 {
            // SAFETY: everyone only reads ρ until the first apply phase.
            let r = unsafe { rho_sh.slice(0, n) };
            r.iter().map(|v| v * v).sum::<f64>()
        } else {
            0.0
        };
        for (r, cursor) in cursors.iter().enumerate() {
            let m = members(r);
            let round_iter = || (0..m).map(move |t| groups[r + t * n_rounds]);
            // --- propose against the round-start residual.
            loop {
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t >= m {
                    break;
                }
                let (g, s, e) = groups[r + t * n_rounds];
                // SAFETY: ρ is read-only during the propose phase; group
                // column ranges are disjoint across workers; β is
                // read-only here.
                let rho_view = unsafe { rho_sh.slice(0, n) };
                let prop = unsafe { proposed.range_mut(s, e) };
                let lg = pb.lipschitz[g];
                if lg == 0.0 {
                    for (off, k) in (s..e).enumerate() {
                        prop[off] = unsafe { beta_sh.get(cols.feature(k)) };
                        unsafe { delta.set(k, 0.0) };
                    }
                    continue;
                }
                let alpha_g = lambda / lg;
                for (off, k) in (s..e).enumerate() {
                    let j = cols.feature(k);
                    prop[off] =
                        unsafe { beta_sh.get(j) } + cols.col_dot(pb, k, rho_view) / lg;
                }
                sgl_prox_inplace(
                    prop,
                    pb.tau * alpha_g,
                    (1.0 - pb.tau) * pb.weights[g] * alpha_g,
                );
                for (off, k) in (s..e).enumerate() {
                    let j = cols.feature(k);
                    unsafe { delta.set(k, prop[off] - beta_sh.get(j)) };
                }
            }
            if !barrier.wait_or(abort) {
                return;
            }
            // --- apply: row-partitioned ρ reduction + per-slice Σρ²;
            // worker 0 commits β (deltas are frozen, nothing reads β
            // except worker 0, who reads before it writes).
            let (row0, row1) = even_chunk(n, threads, w);
            let mut omega_old = 0.0;
            let mut omega_new = 0.0;
            if w == 0 {
                // SAFETY: β commits below happen on this same worker.
                omega_old =
                    round_omega(pb, round_iter(), |k| unsafe { beta_sh.get(cols.feature(k)) });
            }
            let mut slice_sq = 0.0;
            if row0 < row1 {
                // SAFETY: row ranges are disjoint across workers.
                let my_rho = unsafe { rho_sh.range_mut(row0, row1) };
                for (_, s, e) in round_iter() {
                    for k in s..e {
                        // SAFETY: deltas are frozen behind the barrier.
                        let d = unsafe { delta.get(k) };
                        if d != 0.0 {
                            cols.col_axpy_rows(pb, k, -d, row0, row1, my_rho);
                        }
                    }
                }
                slice_sq = my_rho.iter().map(|v| v * v).sum();
            }
            // SAFETY: one slot per worker.
            unsafe { partial.set(w, slice_sq) };
            if w == 0 {
                for (_, s, e) in round_iter() {
                    for k in s..e {
                        if unsafe { delta.get(k) } != 0.0 {
                            // SAFETY: only worker 0 writes β in this phase.
                            unsafe { beta_sh.set(cols.feature(k), proposed.get(k)) };
                        }
                    }
                }
                omega_new = round_omega(pb, round_iter(), |k| unsafe { proposed.get(k) });
            }
            if !barrier.wait_or(abort) {
                return;
            }
            // --- accept test (worker 0), verdict broadcast to the crew.
            if w == 0 {
                // SAFETY: every slot was written before the barrier.
                let rho_sq_new: f64 =
                    (0..threads).map(|i| unsafe { partial.get(i) }).sum();
                let delta_obj =
                    0.5 * (rho_sq_new - rho_sq_old) + lambda * (omega_new - omega_old);
                let slack = 1e-12
                    * (1.0 + rho_sq_old + lambda * (omega_old.abs() + omega_new.abs()));
                if delta_obj <= slack {
                    accepted.store(true, Ordering::SeqCst);
                    rho_sq_old = rho_sq_new;
                } else {
                    accepted.store(false, Ordering::SeqCst);
                }
            }
            if !barrier.wait_or(abort) {
                return;
            }
            if !accepted.load(Ordering::SeqCst) {
                // --- revert the joint step (row-partitioned, like apply)…
                if row0 < row1 {
                    // SAFETY: row ranges are disjoint across workers.
                    let my_rho = unsafe { rho_sh.range_mut(row0, row1) };
                    for (_, s, e) in round_iter() {
                        for k in s..e {
                            let d = unsafe { delta.get(k) };
                            if d != 0.0 {
                                cols.col_axpy_rows(pb, k, d, row0, row1, my_rho);
                            }
                        }
                    }
                }
                if !barrier.wait_or(abort) {
                    return;
                }
                // --- …then redo the round sequentially on worker 0:
                // exact Gauss–Seidel block steps, guaranteed descent.
                if w == 0 {
                    for (_, s, e) in round_iter() {
                        for k in s..e {
                            let d = unsafe { delta.get(k) };
                            if d != 0.0 {
                                // SAFETY: the crew is parked at the next
                                // barrier; worker 0 owns β and ρ here.
                                unsafe {
                                    beta_sh.set(cols.feature(k), proposed.get(k) - d)
                                };
                            }
                        }
                    }
                    let all_rho = unsafe { rho_sh.range_mut(0, n) };
                    let mut block = vec![0.0; max_group];
                    for (g, s, e) in round_iter() {
                        let lg = pb.lipschitz[g];
                        if lg == 0.0 {
                            continue;
                        }
                        let alpha_g = lambda / lg;
                        let width = e - s;
                        for (off, k) in (s..e).enumerate() {
                            let j = cols.feature(k);
                            block[off] = unsafe { beta_sh.get(j) }
                                + cols.col_dot(pb, k, all_rho) / lg;
                        }
                        sgl_prox_inplace(
                            &mut block[..width],
                            pb.tau * alpha_g,
                            (1.0 - pb.tau) * pb.weights[g] * alpha_g,
                        );
                        for (off, k) in (s..e).enumerate() {
                            let j = cols.feature(k);
                            let dd = block[off] - unsafe { beta_sh.get(j) };
                            if dd != 0.0 {
                                unsafe { beta_sh.set(j, block[off]) };
                                cols.col_axpy(pb, k, -dd, all_rho);
                            }
                        }
                    }
                    rho_sq_old = all_rho.iter().map(|v| v * v).sum();
                }
                if !barrier.wait_or(abort) {
                    return;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, Matrix};
    use crate::screening::RuleKind;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn parallel_opts(threads: usize) -> SolveOptions {
        SolveOptions {
            sweep: SweepMode::Parallel,
            sweep_threads: threads,
            ..Default::default()
        }
    }

    fn random_problem(n: usize, n_groups: usize, size: usize, seed: u64) -> SglProblem {
        let groups = Groups::uniform(n_groups, size);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let mut beta_true = vec![0.0; p];
        beta_true[0] = 2.0;
        beta_true[p / 2] = -1.5;
        let xb = x.matvec(&beta_true);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.01 * rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.3)
    }

    #[test]
    fn sweep_mode_round_trip() {
        for m in SweepMode::all() {
            assert_eq!(SweepMode::from_name(m.name()), Some(m));
        }
        assert_eq!(SweepMode::from_name("jacobi"), None);
    }

    #[test]
    fn serial_ctx_never_engages() {
        let ctx = SweepCtx::serial();
        assert!(!ctx.is_parallel());
        assert_eq!(ctx.threads(), 1);
        assert!(!ctx.engage(1 << 20, 1));
        let serial_opts = SolveOptions::default();
        assert!(!SweepCtx::from_opts(&serial_opts).is_parallel());
        // sweep_threads = 1 is explicitly single-threaded: no crew.
        assert!(!SweepCtx::from_opts(&parallel_opts(1)).is_parallel());
    }

    #[test]
    fn parallel_ctx_engages_above_per_worker_floor() {
        let ctx = SweepCtx::from_opts(&parallel_opts(2));
        assert!(ctx.is_parallel());
        assert_eq!(ctx.threads(), 2);
        assert!(ctx.engage(128, 64));
        assert!(!ctx.engage(127, 64));
    }

    #[test]
    fn parallel_per_check_kernels_are_bit_identical() {
        // Sized so every kernel actually crosses its engage() floor with
        // two workers (p = 400 features, 80 groups, ~265 active columns).
        let pb = random_problem(23, 80, 5, 1);
        let spb: SglProblem<CscMatrix> = SglProblem::new(
            CscMatrix::from_dense(&pb.x),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
        );
        let ctx = SweepCtx::from_opts(&parallel_opts(2));
        assert!(ctx.engage(pb.p(), 64), "xt_full must take the parallel branch");
        assert!(ctx.engage(pb.n_groups(), 32), "omega_dual must take the parallel branch");
        let mut rng = Pcg::seeded(9);
        let v: Vec<f64> = (0..pb.n()).map(|_| rng.normal()).collect();
        let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.1).collect();

        // Full Xᵀv.
        let mut serial = vec![0.0; pb.p()];
        pb.x.tmatvec_into(&v, &mut serial);
        let mut par = vec![0.0; pb.p()];
        xt_full(&ctx, &pb, &v, &mut par);
        assert_eq!(serial, par);
        let mut spar = vec![0.0; pb.p()];
        xt_full(&ctx, &spb, &v, &mut spar);
        for (a, b) in serial.iter().zip(&spar) {
            assert!((a - b).abs() < 1e-12);
        }

        // Active-set Xᵀv and residual on a screened-down compaction.
        let mut active = crate::screening::ActiveSet::full(&pb.groups);
        for g in 0..pb.n_groups() {
            if g % 3 == 0 {
                active.group[g] = false;
                let (a, b) = pb.groups.bounds(g);
                for j in a..b {
                    active.feature[j] = false;
                }
            }
        }
        let mut cols = ActiveCols::full(&pb);
        cols.rebuild(&pb, &active);
        assert!(
            ctx.engage(cols.n_active(), 64),
            "xt_active/residual must take the parallel branch"
        );
        let mut xs = vec![0.0; pb.p()];
        cols.xt_into(&pb, &v, &mut xs);
        let mut xp = vec![0.0; pb.p()];
        xt_active(&ctx, &cols, &pb, &v, &mut xp);
        for k in 0..cols.n_active() {
            let j = cols.feature(k);
            assert_eq!(xs[j], xp[j], "feature {j}");
        }

        let mut rs = vec![0.0; pb.n()];
        cols.residual_into(&pb, &beta, &mut rs);
        let mut rp = vec![0.0; pb.n()];
        residual(&ctx, &cols, &pb, &beta, &mut rp);
        assert_eq!(rs, rp);

        // Dual norm.
        let xi: Vec<f64> = (0..pb.p()).map(|_| rng.normal()).collect();
        let a = omega_dual_serial(&xi, &pb.groups, pb.tau, &pb.weights);
        let b = omega_dual(&ctx, &xi, &pb.groups, pb.tau, &pb.weights);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_ista_and_fista_sweeps_are_bit_identical() {
        let pb = random_problem(20, 48, 3, 2);
        let ctx = SweepCtx::from_opts(&parallel_opts(3));
        let cols = ActiveCols::full(&pb);
        let lambda = 0.2 * pb.lambda_max();
        let l_global = crate::solver::ista::global_lipschitz(&pb).max(1e-300);
        let mut rng = Pcg::seeded(11);
        let beta0: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.05).collect();
        let xt_rho: Vec<f64> = (0..pb.p()).map(|_| rng.normal()).collect();
        let mut serial_scratch = ProxScratch::new(3, 1);
        let mut par_scratch = ProxScratch::new(3, ctx.threads());

        let mut bs = beta0.clone();
        let cs = ista_sweep(
            &SweepCtx::serial(),
            &cols,
            &pb,
            lambda,
            l_global,
            &mut bs,
            &xt_rho,
            &mut serial_scratch,
        );
        let mut bp = beta0.clone();
        let cp = ista_sweep(
            &ctx,
            &cols,
            &pb,
            lambda,
            l_global,
            &mut bp,
            &xt_rho,
            &mut par_scratch,
        );
        assert_eq!(bs, bp);
        assert_eq!(cs, cp);

        let inv_l = 1.0 / l_global;
        let mut ns = vec![0.0; pb.p()];
        fista_sweep(
            &SweepCtx::serial(),
            &cols,
            &pb,
            lambda,
            inv_l,
            &beta0,
            &xt_rho,
            &mut ns,
            &mut serial_scratch,
        );
        let mut np = vec![0.0; pb.p()];
        fista_sweep(
            &ctx,
            &cols,
            &pb,
            lambda,
            inv_l,
            &beta0,
            &xt_rho,
            &mut np,
            &mut par_scratch,
        );
        assert_eq!(ns, np);
    }

    #[test]
    fn parallel_cd_epoch_preserves_residual_invariant() {
        // After any number of bulk-synchronous rounds, rho must equal
        // y − Xβ to rounding error (the whole point of the delta
        // reduction between rounds).
        let pb = random_problem(25, 64, 3, 3);
        let ctx = SweepCtx::from_opts(&parallel_opts(4));
        assert!(ctx.engage(pb.n_groups(), 8));
        let mut scratch = CdParScratch::new(pb.p(), ctx.threads());
        let cols = ActiveCols::full(&pb);
        let lambda = 0.15 * pb.lambda_max();
        let mut beta = vec![0.0; pb.p()];
        let mut rho = pb.y.clone();
        for _ in 0..30 {
            cd_epoch_parallel(&ctx, &mut scratch, &pb, &cols, lambda, &mut beta, &mut rho);
        }
        let xb = pb.x.matvec(&beta);
        for i in 0..pb.n() {
            assert!(
                (rho[i] - (pb.y[i] - xb[i])).abs() < 1e-9,
                "row {i}: {} vs {}",
                rho[i],
                pb.y[i] - xb[i]
            );
        }
        // And the epochs actually made progress from the zero start.
        assert!(beta.iter().any(|&b| b != 0.0));
    }

    #[test]
    fn parallel_cd_solve_reaches_the_serial_objective() {
        let pb = random_problem(30, 64, 3, 4);
        let lambda = 0.1 * pb.lambda_max();
        let tol = 1e-10;
        let serial = crate::solver::cd::solve(
            &pb,
            lambda,
            None,
            &SolveOptions { tol, ..Default::default() },
        );
        let par = crate::solver::cd::solve(
            &pb,
            lambda,
            None,
            &SolveOptions { tol, ..parallel_opts(4) },
        );
        assert!(serial.converged && par.converged, "{} / {}", serial.gap, par.gap);
        let objective = |beta: &[f64]| {
            let xb = pb.x.matvec(beta);
            let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
            0.5 * r2
                + lambda * crate::norms::sgl::omega(beta, &pb.groups, pb.tau, &pb.weights)
        };
        let a = objective(&serial.beta);
        let b = objective(&par.beta);
        assert!((a - b).abs() <= 1e-8, "objectives diverged: {a} vs {b}");
        assert_eq!(serial.active.feature, par.active.feature);
        assert_eq!(serial.active.group, par.active.group);
    }

    #[test]
    fn parallel_solvers_with_screening_match_serial_bits_for_ista_fista() {
        // 192 features / 64 groups: with 2 sweep threads the prox sweeps,
        // xt kernels and residual all cross their engage() floors, so the
        // parallel branches really run.
        let pb = random_problem(24, 64, 3, 5);
        let lambda = 0.25 * pb.lambda_max();
        for solver in [crate::solver::SolverKind::Ista, crate::solver::SolverKind::Fista] {
            let mk = |sweep_threads| SolveOptions {
                rule: RuleKind::GapSafe,
                tol: 1e-8,
                max_epochs: 300_000,
                ..if sweep_threads == 0 {
                    SolveOptions::default()
                } else {
                    parallel_opts(sweep_threads)
                }
            };
            let run = |opts: &SolveOptions| match solver {
                crate::solver::SolverKind::Ista => {
                    crate::solver::ista::solve_ista(&pb, lambda, None, opts)
                }
                _ => crate::solver::fista::solve_fista(&pb, lambda, None, opts),
            };
            let serial = run(&mk(0));
            let par = run(&mk(2));
            assert!(serial.converged && par.converged, "{solver:?}");
            // Full-gradient sweeps are Jacobi by construction: the
            // parallel mode must reproduce the serial run bit for bit.
            assert_eq!(serial.beta, par.beta, "{solver:?}");
            assert_eq!(serial.epochs, par.epochs, "{solver:?}");
            assert_eq!(serial.active.feature, par.active.feature, "{solver:?}");
        }
    }
}
