//! Non-overlapping group partitions of the feature set `[p]` (paper §1,
//! Notation). Groups are contiguous column ranges; datasets with scattered
//! group memberships are expected to permute their columns at load time
//! (`data::Dataset` does this), which also gives the solver cache-friendly
//! group blocks.

/// A partition of `0..p` into contiguous, non-overlapping groups.
#[derive(Clone, Debug, PartialEq)]
pub struct Groups {
    /// Half-open `(start, end)` column ranges, in order, covering `0..p`.
    bounds: Vec<(usize, usize)>,
    /// Map feature index -> group index.
    group_of: Vec<usize>,
}

impl Groups {
    /// `n_groups` groups of identical `size` (the paper's synthetic setup:
    /// 1000 groups of 10).
    pub fn uniform(n_groups: usize, size: usize) -> Self {
        assert!(size > 0, "group size must be positive");
        Self::from_sizes(&vec![size; n_groups])
    }

    /// Build from per-group sizes.
    pub fn from_sizes(sizes: &[usize]) -> Self {
        assert!(!sizes.is_empty(), "at least one group required");
        let mut bounds = Vec::with_capacity(sizes.len());
        let mut group_of = Vec::new();
        let mut start = 0;
        for (g, &s) in sizes.iter().enumerate() {
            assert!(s > 0, "group {g} is empty");
            bounds.push((start, start + s));
            group_of.extend(std::iter::repeat(g).take(s));
            start += s;
        }
        Groups { bounds, group_of }
    }

    /// Total number of features `p`.
    #[inline]
    pub fn p(&self) -> usize {
        self.group_of.len()
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.bounds.len()
    }

    /// Half-open column range of group `g`.
    #[inline]
    pub fn bounds(&self, g: usize) -> (usize, usize) {
        self.bounds[g]
    }

    /// Cardinality `n_g`.
    #[inline]
    pub fn size(&self, g: usize) -> usize {
        let (a, b) = self.bounds[g];
        b - a
    }

    /// Group index containing feature `j`.
    #[inline]
    pub fn group_of(&self, j: usize) -> usize {
        self.group_of[j]
    }

    /// Iterate `(g, start, end)`.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize)> + '_ {
        self.bounds.iter().enumerate().map(|(g, &(a, b))| (g, a, b))
    }

    /// The paper's default weights `w_g = sqrt(n_g)` (Simon et al. 2013).
    pub fn sqrt_size_weights(&self) -> Vec<f64> {
        (0..self.n_groups()).map(|g| (self.size(g) as f64).sqrt()).collect()
    }

    /// True if every group has the same size (required by the fixed-shape
    /// XLA artifacts; the native solver handles ragged groups).
    pub fn is_uniform(&self) -> Option<usize> {
        let s = self.size(0);
        if (0..self.n_groups()).all(|g| self.size(g) == s) {
            Some(s)
        } else {
            None
        }
    }

    /// Restriction of a length-`p` vector to group `g`.
    #[inline]
    pub fn slice<'a>(&self, g: usize, x: &'a [f64]) -> &'a [f64] {
        let (a, b) = self.bounds[g];
        &x[a..b]
    }

    /// Mutable restriction of a length-`p` vector to group `g`.
    #[inline]
    pub fn slice_mut<'a>(&self, g: usize, x: &'a mut [f64]) -> &'a mut [f64] {
        let (a, b) = self.bounds[g];
        &mut x[a..b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_partition() {
        let g = Groups::uniform(3, 4);
        assert_eq!(g.p(), 12);
        assert_eq!(g.n_groups(), 3);
        assert_eq!(g.bounds(1), (4, 8));
        assert_eq!(g.size(2), 4);
        assert_eq!(g.is_uniform(), Some(4));
    }

    #[test]
    fn ragged_partition() {
        let g = Groups::from_sizes(&[2, 5, 1]);
        assert_eq!(g.p(), 8);
        assert_eq!(g.bounds(0), (0, 2));
        assert_eq!(g.bounds(1), (2, 7));
        assert_eq!(g.bounds(2), (7, 8));
        assert_eq!(g.is_uniform(), None);
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(6), 1);
        assert_eq!(g.group_of(7), 2);
    }

    #[test]
    fn weights_sqrt_size() {
        let g = Groups::from_sizes(&[4, 9]);
        assert_eq!(g.sqrt_size_weights(), vec![2.0, 3.0]);
    }

    #[test]
    fn slicing() {
        let g = Groups::from_sizes(&[2, 3]);
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(g.slice(1, &x), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn iter_covers_partition() {
        let g = Groups::from_sizes(&[1, 2, 3]);
        let triples: Vec<_> = g.iter().collect();
        assert_eq!(triples, vec![(0, 0, 1), (1, 1, 3), (2, 3, 6)]);
    }

    #[test]
    #[should_panic]
    fn empty_group_rejected() {
        Groups::from_sizes(&[2, 0, 1]);
    }
}
