//! The shared active-set core every native solver drives.
//!
//! PR 1 gave the CD solver active-set column compaction and the
//! `on_solve_complete` terminal-dual handoff; ISTA and FISTA were left
//! behind. This module hoists that machinery out of `cd.rs` so all three
//! solvers share it, generic over the [`Design`] backend:
//!
//! - [`ActiveCols`] — compaction bookkeeping. After a screening event the
//!   surviving columns of `X` are packed into a fresh backend instance
//!   ([`Design::select_cols`]: a contiguous dense scratch for `Matrix`, a
//!   pruned CSC for `CscMatrix`) so the per-epoch sweeps stream packed
//!   memory instead of hopping across screened-out gaps.
//! - [`ScreenState`] — the gap-check/screening-event plumbing: applying
//!   the rule's sphere, rebuilding the compaction, re-evaluating a stale
//!   gap when screening zeroed nonzero coordinates, recording history,
//!   and handing the terminal dual point to sequential rules through
//!   [`ScreeningRule::on_solve_complete`].
//!
//! Packing is **lazy**: until the first screening event the active set is
//! full and every column of `pb.x` is already addressable, so the initial
//! state is just the identity mapping — no copy. Rebuilds are monotone
//! (the active set only shrinks along a solve).

use super::cd::{CheckEvent, SolveOptions, SolveResult};
use super::datafit::{Datafit, FitState};
use super::duality::DualSnapshot;
use super::problem::SglProblem;
use super::sweep::SweepCtx;
use crate::linalg::Design;
use crate::screening::{apply_sphere_state, ActiveSet, ScreeningRule};
use crate::util::timer::Stopwatch;
use crate::util::trace;

/// Compacted view of the active columns: a packed backend instance plus
/// the bookkeeping mapping compact columns back to original features.
pub struct ActiveCols<D: Design> {
    /// Packed design over the active columns; `None` until the first
    /// screening event (read through `pb.x` with the identity mapping).
    compact: Option<D>,
    /// Original feature index of each compact column.
    col_feat: Vec<usize>,
    /// `(g, start, end)` compact-column ranges, one per surviving group
    /// with at least one surviving feature.
    groups: Vec<(usize, usize, usize)>,
}

impl<D: Design> ActiveCols<D> {
    /// Identity mapping over the full active set; no data is copied.
    pub fn full<F: Datafit>(pb: &SglProblem<D, F>) -> Self {
        ActiveCols {
            compact: None,
            col_feat: (0..pb.p()).collect(),
            groups: pb.groups.iter().collect(),
        }
    }

    /// Re-pack from the current active set, reusing the index buffers.
    pub fn rebuild<F: Datafit>(&mut self, pb: &SglProblem<D, F>, active: &ActiveSet) {
        self.col_feat.clear();
        self.groups.clear();
        for (g, a, b) in pb.groups.iter() {
            if !active.group[g] {
                continue;
            }
            let start = self.col_feat.len();
            for j in a..b {
                if active.feature[j] {
                    self.col_feat.push(j);
                }
            }
            let end = self.col_feat.len();
            if end > start {
                self.groups.push((g, start, end));
            }
        }
        self.compact = Some(pb.x.select_cols(&self.col_feat));
    }

    /// Compact `(group, start, end)` ranges of the surviving groups.
    #[inline]
    pub fn groups(&self) -> &[(usize, usize, usize)] {
        &self.groups
    }

    /// Original feature index of compact column `k`.
    #[inline]
    pub fn feature(&self, k: usize) -> usize {
        self.col_feat[k]
    }

    /// Number of active (compact) columns.
    #[inline]
    pub fn n_active(&self) -> usize {
        self.col_feat.len()
    }

    /// `X_kᵀ v` for compact column `k`.
    #[inline]
    pub fn col_dot<F: Datafit>(&self, pb: &SglProblem<D, F>, k: usize, v: &[f64]) -> f64 {
        match &self.compact {
            Some(m) => m.col_dot(k, v),
            None => pb.x.col_dot(self.col_feat[k], v),
        }
    }

    /// `out += alpha · X_k` for compact column `k`.
    #[inline]
    pub fn col_axpy<F: Datafit>(
        &self,
        pb: &SglProblem<D, F>,
        k: usize,
        alpha: f64,
        out: &mut [f64],
    ) {
        match &self.compact {
            Some(m) => m.col_axpy(k, alpha, out),
            None => pb.x.col_axpy(self.col_feat[k], alpha, out),
        }
    }

    /// `out += alpha · X_k[row0..row1]` for compact column `k` — the
    /// row-windowed axpy the row-partitioned parallel kernels
    /// ([`crate::solver::sweep`]) are built on.
    #[inline]
    pub fn col_axpy_rows<F: Datafit>(
        &self,
        pb: &SglProblem<D, F>,
        k: usize,
        alpha: f64,
        row0: usize,
        row1: usize,
        out: &mut [f64],
    ) {
        match &self.compact {
            Some(m) => m.col_axpy_rows(k, alpha, row0, row1, out),
            None => pb.x.col_axpy_rows(self.col_feat[k], alpha, row0, row1, out),
        }
    }

    /// `rho = y − Xβ`, touching only the active columns (screened
    /// coordinates of `β` are zero by construction).
    pub fn residual_into<F: Datafit>(
        &self,
        pb: &SglProblem<D, F>,
        beta: &[f64],
        rho: &mut [f64],
    ) {
        rho.copy_from_slice(&pb.y);
        for k in 0..self.col_feat.len() {
            let bj = beta[self.col_feat[k]];
            if bj != 0.0 {
                self.col_axpy(pb, k, -bj, rho);
            }
        }
    }

    /// `xb = Xβ`, touching only the active columns — the linear-predictor
    /// counterpart of [`residual_into`](Self::residual_into) for datafits
    /// whose maintained state is `Xβ` (logistic).
    pub fn linear_predictor_into<F: Datafit>(
        &self,
        pb: &SglProblem<D, F>,
        beta: &[f64],
        xb: &mut [f64],
    ) {
        xb.fill(0.0);
        for k in 0..self.col_feat.len() {
            let bj = beta[self.col_feat[k]];
            if bj != 0.0 {
                self.col_axpy(pb, k, bj, xb);
            }
        }
    }

    /// `xt[j] = X_jᵀ v` for every active feature `j` (entries of screened
    /// features are left untouched — callers must not read them).
    pub fn xt_into<F: Datafit>(&self, pb: &SglProblem<D, F>, v: &[f64], xt: &mut [f64]) {
        for k in 0..self.col_feat.len() {
            xt[self.col_feat[k]] = self.col_dot(pb, k, v);
        }
    }
}

/// Outcome of one gap-evaluation checkpoint.
#[derive(Clone, Copy, Debug)]
pub struct GapCheckOutcome {
    /// The (possibly re-evaluated) gap reached the tolerance.
    pub converged: bool,
    /// Features eliminated at this checkpoint.
    pub features_screened: usize,
}

/// Per-solve screening/convergence state shared by CD, ISTA and FISTA.
pub struct ScreenState<D: Design> {
    pub active: ActiveSet,
    pub cols: ActiveCols<D>,
    /// Intra-solve sweep context ([`crate::solver::sweep`]): owns the
    /// per-solve worker crew when `sweep = "parallel"`, serial otherwise.
    /// Solvers route their epoch kernels through it; the gap-check and
    /// screening plumbing below does the same.
    pub sweep: SweepCtx,
    pub history: Vec<CheckEvent>,
    pub gap: f64,
    pub gap_evals: usize,
    pub converged: bool,
    final_snap: Option<DualSnapshot>,
    tol_abs: f64,
    record_history: bool,
}

impl<D: Design> ScreenState<D> {
    pub fn new<F: Datafit>(pb: &SglProblem<D, F>, opts: &SolveOptions) -> Self {
        // Stopping threshold relative to the datafit's natural gap scale
        // (`‖y‖²` quadratic, `n·ln 2` logistic; see SolveOptions::tol).
        let tol_abs = opts.tol * pb.datafit.gap_scale(&pb.y).max(f64::MIN_POSITIVE);
        ScreenState {
            active: ActiveSet::full(&pb.groups),
            cols: ActiveCols::full(pb),
            sweep: SweepCtx::from_opts(opts),
            history: Vec::new(),
            gap: f64::INFINITY,
            gap_evals: 0,
            converged: false,
            final_snap: None,
            tol_abs,
            record_history: opts.record_history,
        }
    }

    /// Absolute gap tolerance (`opts.tol · ‖y‖²`).
    #[inline]
    pub fn tol_abs(&self) -> f64 {
        self.tol_abs
    }

    /// One gap-evaluation checkpoint: screen with the rule's sphere,
    /// rebuild the compaction if features died, re-evaluate the gap if
    /// screening zeroed nonzero coordinates on a converging check, record
    /// history, and decide convergence. `snap` must be computed from the
    /// *current* `beta`/`state` by the caller (solvers differ in how they
    /// obtain `Xᵀρ`).
    #[allow(clippy::too_many_arguments)]
    pub fn gap_check<F: Datafit>(
        &mut self,
        pb: &SglProblem<D, F>,
        lambda: f64,
        epoch: usize,
        rule: &mut dyn ScreeningRule<D, F>,
        beta: &mut [f64],
        fit: &mut FitState,
        snap: DualSnapshot,
        sw: &Stopwatch,
    ) -> GapCheckOutcome {
        let mut snap = snap;
        self.gap = snap.gap;
        self.gap_evals += 1;
        // 0-based checkpoint index within this solve, for trace sampling.
        let trace_seq = (self.gap_evals - 1) as u64;
        let mut features_screened = 0;
        // Screen first (even on the converging check: the final active
        // sets reported for Fig. 2a/2b use the tightest sphere).
        if let Some(sphere) = rule.sphere(pb, lambda, &snap) {
            let out =
                apply_sphere_state(pb, &sphere, &mut self.active, beta, fit, &self.sweep);
            features_screened = out.features_screened;
            if out.features_screened > 0 {
                self.cols.rebuild(pb, &self.active);
            }
            if out.beta_changed && self.gap <= self.tol_abs {
                // Screening zeroed nonzero coords on a converging check:
                // the cached gap is stale, recompute before deciding.
                snap = DualSnapshot::compute_state_ctx(
                    pb,
                    beta,
                    fit.as_ref(),
                    lambda,
                    &self.sweep,
                );
                self.gap = snap.gap;
                self.gap_evals += 1;
            }
        }
        if self.record_history {
            self.history.push(CheckEvent {
                epoch,
                gap: self.gap,
                radius: snap.radius,
                active_features: self.active.n_active_features(),
                active_groups: self.active.n_active_groups(),
                elapsed_s: sw.elapsed_s(),
            });
        }
        // Observation only — nothing below feeds back into the solve
        // (the disabled-tracing bit-identity tests pin this). Rejection-
        // rate-vs-λ curves (paper Fig. 2) fall out of these events on any
        // production solve, not just the fig experiments.
        crate::util::progress::report(epoch, self.gap);
        if trace::sampled(trace_seq) {
            trace::instant("gap_check", || {
                vec![
                    ("lambda", lambda.into()),
                    ("epoch", epoch.into()),
                    ("gap", self.gap.into()),
                    ("screened", features_screened.into()),
                    ("active_features", self.active.n_active_features().into()),
                    ("active_groups", self.active.n_active_groups().into()),
                    ("rule", rule.kind().name().into()),
                    ("datafit", pb.datafit.kind().name().into()),
                    ("tasks", pb.datafit.tasks().into()),
                    ("kernel", crate::linalg::simd::effective().name().into()),
                ]
            });
        }
        self.final_snap = Some(snap);
        if self.gap <= self.tol_abs {
            self.converged = true;
        }
        GapCheckOutcome { converged: self.converged, features_screened }
    }

    /// Terminal bookkeeping shared by every solver: if the epoch budget
    /// ran out before a converging check, evaluate the true terminal gap;
    /// then hand the terminal dual point to the rule — sequential rules
    /// ([`crate::screening::RuleKind::GapSafeSeq`]) carry it to the next
    /// grid point of a warm-started path.
    pub fn finalize<F: Datafit>(
        &mut self,
        pb: &SglProblem<D, F>,
        lambda: f64,
        rule: &mut dyn ScreeningRule<D, F>,
        beta: &[f64],
        fit: &FitState,
    ) {
        if !self.converged {
            let snap =
                DualSnapshot::compute_state_ctx(pb, beta, fit.as_ref(), lambda, &self.sweep);
            self.gap = snap.gap;
            self.gap_evals += 1;
            self.converged = self.gap <= self.tol_abs;
            self.final_snap = Some(snap);
        }
        if let Some(snap) = &self.final_snap {
            rule.on_solve_complete(pb, lambda, snap);
        }
    }

    /// Package the terminal state into a [`SolveResult`].
    pub fn into_result(self, beta: Vec<f64>, epochs: usize, elapsed_s: f64) -> SolveResult {
        SolveResult {
            beta,
            gap: self.gap,
            epochs,
            converged: self.converged,
            elapsed_s,
            active: self.active,
            history: self.history,
            gap_evals: self.gap_evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, Matrix};
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn dense_problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[3, 3, 2]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(10, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.4)
    }

    #[test]
    fn identity_mapping_before_rebuild() {
        let pb = dense_problem(1);
        let cols = ActiveCols::full(&pb);
        assert_eq!(cols.n_active(), pb.p());
        assert_eq!(cols.groups().len(), pb.n_groups());
        let v: Vec<f64> = (0..pb.n()).map(|i| i as f64).collect();
        for k in 0..pb.p() {
            assert_eq!(cols.feature(k), k);
            let direct = crate::linalg::ops::dot(pb.x.col(k), &v);
            assert!((cols.col_dot(&pb, k, &v) - direct).abs() < 1e-14);
        }
    }

    #[test]
    fn rebuild_packs_surviving_columns() {
        let pb = dense_problem(2);
        let mut active = ActiveSet::full(&pb.groups);
        // Screen group 1 entirely plus feature 2 of group 0.
        active.group[1] = false;
        for j in 3..6 {
            active.feature[j] = false;
        }
        active.feature[2] = false;
        let mut cols = ActiveCols::full(&pb);
        cols.rebuild(&pb, &active);
        assert_eq!(cols.n_active(), 4); // features 0, 1, 6, 7
        assert_eq!(cols.groups(), &[(0, 0, 2), (2, 2, 4)]);
        let v: Vec<f64> = (0..pb.n()).map(|i| (i as f64).sin()).collect();
        for (k, &j) in [0usize, 1, 6, 7].iter().enumerate() {
            assert_eq!(cols.feature(k), j);
            let direct = crate::linalg::ops::dot(pb.x.col(j), &v);
            assert!((cols.col_dot(&pb, k, &v) - direct).abs() < 1e-14);
        }
        // Residual over active columns only.
        let mut beta = vec![0.0; pb.p()];
        beta[0] = 0.5;
        beta[6] = -1.0;
        let mut rho = vec![0.0; pb.n()];
        cols.residual_into(&pb, &beta, &mut rho);
        let xb = pb.x.matvec(&beta);
        for i in 0..pb.n() {
            assert!((rho[i] - (pb.y[i] - xb[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn csc_backend_compacts_identically() {
        let pb = dense_problem(3);
        let spb: SglProblem<CscMatrix> = SglProblem::new(
            CscMatrix::from_dense(&pb.x),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
        );
        let mut active = ActiveSet::full(&pb.groups);
        active.feature[1] = false;
        active.feature[4] = false;
        let mut dc = ActiveCols::full(&pb);
        dc.rebuild(&pb, &active);
        let mut sc = ActiveCols::full(&spb);
        sc.rebuild(&spb, &active);
        assert_eq!(dc.n_active(), sc.n_active());
        assert_eq!(dc.groups(), sc.groups());
        let v: Vec<f64> = (0..pb.n()).map(|i| (i as f64 + 0.5).cos()).collect();
        for k in 0..dc.n_active() {
            assert_eq!(dc.feature(k), sc.feature(k));
            let a = dc.col_dot(&pb, k, &v);
            let b = sc.col_dot(&spb, k, &v);
            assert!((a - b).abs() < 1e-12, "col {k}: {a} vs {b}");
        }
    }
}
