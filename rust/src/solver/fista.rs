//! FISTA: accelerated proximal gradient with GAP safe screening.
//!
//! The paper's Algorithm 2 is un-accelerated ISTA-BC; the GAP safe
//! machinery is solver-agnostic (any primal sequence `β_k` gives a dual
//! point by Eq. 15), so acceleration composes freely. This is the
//! Beck–Teboulle momentum scheme on the compacted full-gradient iteration
//! of [`super::ista`] (same shared active-set core, same
//! `on_solve_complete` handoff for sequential rules), with two standard
//! safeguards:
//!
//! - **screening restart** — eliminating variables moves the iterate
//!   discontinuously, so the momentum sequence restarts whenever the
//!   active set shrinks;
//! - **function-value restart** — if the primal objective increases
//!   (possible under momentum), restart (O'Donoghue & Candès).

use super::active_set::ScreenState;
use super::datafit::Datafit;
use super::duality::DualSnapshot;
use super::ista::global_step_lipschitz;
use super::problem::SglProblem;
use super::sweep;
use crate::linalg::Design;
use crate::screening::{make_rule, ScreeningRule};
use crate::solver::cd::{SolveOptions, SolveResult};
use crate::util::timer::Stopwatch;
use crate::util::trace;

/// FISTA solve at a single `λ`. Interface mirrors `cd::solve`.
pub fn solve_fista<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let mut rule = make_rule(opts.rule, pb);
    solve_fista_with_rule(pb, lambda, beta0, opts, rule.as_mut())
}

/// FISTA with a caller-provided rule instance (path solves construct the
/// rule once and carry it across the grid, exactly like `cd`).
pub fn solve_fista_with_rule<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
    rule: &mut dyn ScreeningRule<D, F>,
) -> SolveResult {
    assert!(lambda > 0.0, "lambda must be positive");
    let sw = Stopwatch::start();
    let p = pb.p();
    let _solve_span = trace::span_with("solve", || {
        vec![("solver", "fista".into()), ("lambda", lambda.into()), ("p", p.into())]
    });
    let q = pb.datafit.tasks();
    let inv_l = 1.0 / global_step_lipschitz(pb).max(1e-300);
    let mut state = ScreenState::new(pb, opts);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p * q]);
    assert_eq!(beta.len(), p * q, "warm start must be feature-major p * tasks");
    let mut z = beta.clone(); // extrapolated point
    let mut beta_next = beta.clone();
    let mut t_k = 1.0_f64;
    let mut epochs_done = 0usize;
    // Scratch datafit state, refreshed for whichever iterate (β, z or
    // β⁺) the next step reads.
    let mut fit = pb.datafit.init_state(&pb.x, &pb.y, &beta);
    let mut xt_rho = vec![0.0; p * q];
    let mut prev_obj = f64::INFINITY;
    // Per-worker prox blocks, allocated once for the whole solve (d × q
    // panels in the multi-task case).
    let max_group = (0..pb.n_groups()).map(|g| pb.groups.size(g)).max().unwrap_or(0);
    let mut prox_scratch = sweep::ProxScratch::new(max_group * q, state.sweep.threads());

    for epoch in 0..opts.max_epochs {
        if epoch % opts.fce == 0 {
            sweep::refresh_state(&state.sweep, &state.cols, pb, &beta, &mut fit);
            let snap =
                DualSnapshot::compute_state_ctx(pb, &beta, fit.as_ref(), lambda, &state.sweep);
            let out =
                state.gap_check(pb, lambda, epoch, rule, &mut beta, &mut fit, snap, &sw);
            if out.features_screened > 0 {
                // Screening restart: the extrapolation history is stale,
                // and the scratch iterates must drop the dead coordinates
                // (apply_sphere zeroed them in `beta`).
                z.copy_from_slice(&beta);
                beta_next.copy_from_slice(&beta);
                t_k = 1.0;
                prev_obj = f64::INFINITY;
            }
            if out.converged {
                epochs_done = epoch;
                break;
            }
        }

        // Gradient step at the extrapolated point z, over the compacted
        // active columns only — all three sweeps through the sweep
        // context (parallel branches are bit-identical to the serial
        // loops: the prox reads a fixed Xᵀρ, the residual accumulates in
        // serial column order per row).
        sweep::refresh_state(&state.sweep, &state.cols, pb, &z, &mut fit);
        sweep::xt_active(&state.sweep, &state.cols, pb, fit.residual(), &mut xt_rho);
        let mu = pb.datafit.ridge();
        if mu != 0.0 {
            // Ridge term of the gradient at the extrapolated point. No
            // ridge-carrying datafit is multi-task today.
            debug_assert_eq!(q, 1, "ridge gradient path is scalar-only");
            for k in 0..state.cols.n_active() {
                let j = state.cols.feature(k);
                xt_rho[j] -= mu * z[j];
            }
        }
        sweep::fista_sweep(
            &state.sweep,
            &state.cols,
            pb,
            lambda,
            inv_l,
            &z,
            &xt_rho,
            &mut beta_next,
            &mut prox_scratch,
        );

        // Function-value restart check.
        sweep::refresh_state(&state.sweep, &state.cols, pb, &beta_next, &mut fit);
        let obj =
            crate::solver::duality::primal_value_state(pb, &beta_next, &fit.main, lambda);
        if obj > prev_obj {
            // Restart: fall back to a plain ISTA step from beta.
            t_k = 1.0;
            z.copy_from_slice(&beta);
            prev_obj = f64::INFINITY;
            epochs_done = epoch + 1;
            continue;
        }
        prev_obj = obj;

        // Momentum update on the active coordinates (screened ones are
        // zero in beta, beta_next and z alike). The per-entry expression
        // is the same at every q, so the q = 1 iterates are bit-identical
        // to the historical scalar loop (`j * 1 + 0 == j`).
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
        let coef = (t_k - 1.0) / t_next;
        for k in 0..state.cols.n_active() {
            let j = state.cols.feature(k);
            for i in j * q..(j + 1) * q {
                z[i] = beta_next[i] + coef * (beta_next[i] - beta[i]);
                beta[i] = beta_next[i];
            }
        }
        t_k = t_next;
        epochs_done = epoch + 1;
    }

    // `fit` may hold the state of z/beta_next; finalize() recomputes
    // the terminal gap from `beta` only when convergence is still open.
    sweep::refresh_state(&state.sweep, &state.cols, pb, &beta, &mut fit);
    state.finalize(pb, lambda, rule, &beta, &fit);
    state.into_result(beta, epochs_done, sw.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::screening::RuleKind;
    use crate::solver::{cd, ista};

    fn problem(seed: u64) -> SglProblem {
        let cfg = SyntheticConfig {
            n: 50,
            n_groups: 20,
            group_size: 5,
            gamma1: 4,
            gamma2: 3,
            seed,
            ..Default::default()
        };
        let d = generate(&cfg);
        SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3)
    }

    #[test]
    fn fista_matches_cd_solution() {
        let pb = problem(1);
        let lambda = 0.15 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, max_epochs: 200_000, ..Default::default() };
        let a = cd::solve(&pb, lambda, None, &opts);
        let f = solve_fista(&pb, lambda, None, &opts);
        assert!(a.converged && f.converged, "cd={} fista={}", a.gap, f.gap);
        for j in 0..pb.p() {
            assert!((a.beta[j] - f.beta[j]).abs() < 5e-4, "j={j}");
        }
    }

    #[test]
    fn fista_beats_ista_in_epochs() {
        let pb = problem(2);
        let lambda = 0.1 * pb.lambda_max();
        let opts = SolveOptions {
            tol: 1e-8,
            max_epochs: 500_000,
            rule: RuleKind::None,
            record_history: false,
            ..Default::default()
        };
        let plain = ista::solve_ista(&pb, lambda, None, &opts);
        let fast = solve_fista(&pb, lambda, None, &opts);
        assert!(plain.converged && fast.converged);
        assert!(
            fast.epochs < plain.epochs,
            "fista {} vs ista {} epochs",
            fast.epochs,
            plain.epochs
        );
    }

    #[test]
    fn fista_with_screening_converges_and_is_safe() {
        let pb = problem(3);
        let lambda = 0.3 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-9, rule: RuleKind::GapSafe, ..Default::default() };
        let res = solve_fista(&pb, lambda, None, &opts);
        assert!(res.converged);
        let reference = cd::solve(
            &pb,
            lambda,
            None,
            &SolveOptions { tol: 1e-12, rule: RuleKind::None, ..Default::default() },
        );
        for j in 0..pb.p() {
            if !res.active.feature[j] {
                assert!(reference.beta[j].abs() < 1e-7, "screened live feature {j}");
            }
        }
    }

    #[test]
    fn multitask_fista_matches_cd() {
        use crate::linalg::Matrix;
        use crate::solver::datafit::MultiTaskQuadratic;
        use crate::solver::groups::Groups;
        use crate::util::rng::Pcg;
        let q = 3;
        let groups = Groups::from_sizes(&[3, 3, 2]);
        let p = groups.p();
        let n = 18;
        let mut rng = Pcg::seeded(13);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        let w = groups.sqrt_size_weights();
        let pb = SglProblem::with_datafit(x, y, groups, 0.4, w, MultiTaskQuadratic::new(q));
        let lambda = 0.2 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, max_epochs: 200_000, ..Default::default() };
        let a = cd::solve(&pb, lambda, None, &opts);
        let f = solve_fista(&pb, lambda, None, &opts);
        assert!(a.converged && f.converged, "cd={} fista={}", a.gap, f.gap);
        for i in 0..p * q {
            assert!((a.beta[i] - f.beta[i]).abs() < 5e-4, "i={i}");
        }
    }

    #[test]
    fn zero_above_lambda_max() {
        let pb = problem(4);
        let res = solve_fista(&pb, 1.3 * pb.lambda_max(), None, &SolveOptions::default());
        assert!(res.converged);
        assert!(res.beta.iter().all(|&b| b == 0.0));
    }
}
