//! FISTA: accelerated proximal gradient with GAP safe screening.
//!
//! The paper's Algorithm 2 is un-accelerated ISTA-BC; the GAP safe
//! machinery is solver-agnostic (any primal sequence `β_k` gives a dual
//! point by Eq. 15), so acceleration composes freely. This is the
//! Beck–Teboulle momentum scheme on the masked full-gradient iteration of
//! [`super::ista`], with two standard safeguards:
//!
//! - **screening restart** — eliminating variables moves the iterate
//!   discontinuously, so the momentum sequence restarts whenever the
//!   active set shrinks;
//! - **function-value restart** — if the primal objective increases
//!   (possible under momentum), restart (O'Donoghue & Candès).

use super::duality::DualSnapshot;
use super::ista::global_lipschitz;
use super::problem::SglProblem;
use crate::norms::prox::sgl_prox_inplace;
use crate::screening::{apply_sphere, make_rule, ActiveSet};
use crate::solver::cd::{CheckEvent, SolveOptions, SolveResult};
use crate::util::timer::Stopwatch;

/// FISTA solve at a single `λ`. Interface mirrors `cd::solve`.
pub fn solve_fista(
    pb: &SglProblem,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let sw = Stopwatch::start();
    let p = pb.p();
    let tol_abs = opts.tol * crate::linalg::ops::l2_norm_sq(&pb.y).max(f64::MIN_POSITIVE);
    let inv_l = 1.0 / global_lipschitz(pb).max(1e-300);
    let mut rule = make_rule(opts.rule, pb);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut z = beta.clone(); // extrapolated point
    let mut t_k = 1.0_f64;
    let mut active = ActiveSet::full(&pb.groups);
    let mut history = Vec::new();
    let mut gap = f64::INFINITY;
    let mut gap_evals = 0usize;
    let mut converged = false;
    let mut epochs_done = 0usize;
    let mut rho = vec![0.0; pb.n()];
    let mut xt_rho = vec![0.0; p];
    let mut prev_obj = f64::INFINITY;

    let objective = |pbv: &SglProblem, b: &[f64], r: &[f64]| {
        crate::solver::duality::primal_value(pbv, b, r, lambda)
    };
    let residual_of = |pbv: &SglProblem, b: &[f64], out: &mut Vec<f64>| {
        pbv.x.matvec_into(b, out);
        for (ri, yi) in out.iter_mut().zip(&pbv.y) {
            *ri = yi - *ri;
        }
    };

    for epoch in 0..opts.max_epochs {
        if epoch % opts.fce == 0 {
            residual_of(pb, &beta, &mut rho);
            let snap = DualSnapshot::compute(pb, &beta, &rho, lambda);
            gap = snap.gap;
            gap_evals += 1;
            if let Some(sphere) = rule.sphere(pb, lambda, &snap) {
                let before = active.n_active_features();
                let out = apply_sphere(pb, &sphere, &mut active, &mut beta, &mut rho);
                if active.n_active_features() < before {
                    // Screening restart: the extrapolation history is stale.
                    z.copy_from_slice(&beta);
                    t_k = 1.0;
                }
                if out.beta_changed && gap <= tol_abs {
                    let snap2 = DualSnapshot::compute(pb, &beta, &rho, lambda);
                    gap = snap2.gap;
                    gap_evals += 1;
                }
            }
            if opts.record_history {
                history.push(CheckEvent {
                    epoch,
                    gap,
                    radius: snap.radius,
                    active_features: active.n_active_features(),
                    active_groups: active.n_active_groups(),
                    elapsed_s: sw.elapsed_s(),
                });
            }
            if gap <= tol_abs {
                converged = true;
                epochs_done = epoch;
                break;
            }
        }

        // Gradient step at the extrapolated point z.
        residual_of(pb, &z, &mut rho);
        pb.x.tmatvec_into(&rho, &mut xt_rho);
        let mut beta_next = vec![0.0; p];
        for (g, a, b) in pb.groups.iter() {
            if !active.group[g] {
                continue;
            }
            let d = b - a;
            let mut block: Vec<f64> = (a..b)
                .map(|j| if active.feature[j] { z[j] + xt_rho[j] * inv_l } else { 0.0 })
                .collect();
            sgl_prox_inplace(
                &mut block[..d],
                pb.tau * lambda * inv_l,
                (1.0 - pb.tau) * pb.weights[g] * lambda * inv_l,
            );
            for (k, j) in (a..b).enumerate() {
                beta_next[j] = if active.feature[j] { block[k] } else { 0.0 };
            }
        }

        // Function-value restart check.
        residual_of(pb, &beta_next, &mut rho);
        let obj = objective(pb, &beta_next, &rho);
        if obj > prev_obj {
            // Restart: fall back to a plain ISTA step from beta.
            t_k = 1.0;
            z.copy_from_slice(&beta);
            prev_obj = f64::INFINITY;
            epochs_done = epoch + 1;
            continue;
        }
        prev_obj = obj;

        // Momentum update.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
        let coef = (t_k - 1.0) / t_next;
        for j in 0..p {
            z[j] = beta_next[j] + coef * (beta_next[j] - beta[j]);
        }
        beta = beta_next;
        t_k = t_next;
        epochs_done = epoch + 1;
    }

    if !converged {
        residual_of(pb, &beta, &mut rho);
        let snap = DualSnapshot::compute(pb, &beta, &rho, lambda);
        gap = snap.gap;
        gap_evals += 1;
        converged = gap <= tol_abs;
    }

    SolveResult {
        beta,
        gap,
        epochs: epochs_done,
        converged,
        elapsed_s: sw.elapsed_s(),
        active,
        history,
        gap_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::screening::RuleKind;
    use crate::solver::{cd, ista};

    fn problem(seed: u64) -> SglProblem {
        let cfg = SyntheticConfig {
            n: 50,
            n_groups: 20,
            group_size: 5,
            gamma1: 4,
            gamma2: 3,
            seed,
            ..Default::default()
        };
        let d = generate(&cfg);
        SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3)
    }

    #[test]
    fn fista_matches_cd_solution() {
        let pb = problem(1);
        let lambda = 0.15 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, max_epochs: 200_000, ..Default::default() };
        let a = cd::solve(&pb, lambda, None, &opts);
        let f = solve_fista(&pb, lambda, None, &opts);
        assert!(a.converged && f.converged, "cd={} fista={}", a.gap, f.gap);
        for j in 0..pb.p() {
            assert!((a.beta[j] - f.beta[j]).abs() < 5e-4, "j={j}");
        }
    }

    #[test]
    fn fista_beats_ista_in_epochs() {
        let pb = problem(2);
        let lambda = 0.1 * pb.lambda_max();
        let opts = SolveOptions {
            tol: 1e-8,
            max_epochs: 500_000,
            rule: RuleKind::None,
            record_history: false,
            ..Default::default()
        };
        let plain = ista::solve_ista(&pb, lambda, None, &opts);
        let fast = solve_fista(&pb, lambda, None, &opts);
        assert!(plain.converged && fast.converged);
        assert!(
            fast.epochs < plain.epochs,
            "fista {} vs ista {} epochs",
            fast.epochs,
            plain.epochs
        );
    }

    #[test]
    fn fista_with_screening_converges_and_is_safe() {
        let pb = problem(3);
        let lambda = 0.3 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-9, rule: RuleKind::GapSafe, ..Default::default() };
        let res = solve_fista(&pb, lambda, None, &opts);
        assert!(res.converged);
        let reference = cd::solve(
            &pb,
            lambda,
            None,
            &SolveOptions { tol: 1e-12, rule: RuleKind::None, ..Default::default() },
        );
        for j in 0..pb.p() {
            if !res.active.feature[j] {
                assert!(reference.beta[j].abs() < 1e-7, "screened live feature {j}");
            }
        }
    }

    #[test]
    fn zero_above_lambda_max() {
        let pb = problem(4);
        let res = solve_fista(&pb, 1.3 * pb.lambda_max(), None, &SolveOptions::default());
        assert!(res.converged);
        assert!(res.beta.iter().all(|&b| b == 0.0));
    }
}
