//! Primal/dual objectives, dual-point construction by residual scaling
//! (paper Eq. 15), duality gap, and the GAP safe radius (Theorem 2).

use super::problem::SglProblem;
use super::sweep::{self, SweepCtx};
use crate::linalg::ops::{l2_norm, l2_norm_sq};
use crate::linalg::Design;
use crate::norms::sgl::omega;

/// Primal objective `P_{λ,τ,w}(β) = ½‖ρ‖² + λΩ(β)` given the residual
/// `ρ = y − Xβ` (kept up to date by the solvers; never recomputed here).
pub fn primal_value<D: Design>(
    pb: &SglProblem<D>,
    beta: &[f64],
    residual: &[f64],
    lambda: f64,
) -> f64 {
    0.5 * l2_norm_sq(residual) + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

/// Dual objective `D_λ(θ) = ½‖y‖² − λ²/2 ‖θ − y/λ‖²` (Eq. 6).
pub fn dual_value(y: &[f64], theta: &[f64], lambda: f64) -> f64 {
    debug_assert_eq!(y.len(), theta.len());
    let dist_sq: f64 = y
        .iter()
        .zip(theta)
        .map(|(yi, ti)| {
            let d = ti - yi / lambda;
            d * d
        })
        .sum();
    0.5 * l2_norm_sq(y) - 0.5 * lambda * lambda * dist_sq
}

/// A dual-feasible point built from the current residual plus everything
/// the screening rules need alongside it.
#[derive(Clone, Debug)]
pub struct DualSnapshot {
    /// Dual feasible `θ = ρ / max(λ, Ω^D(Xᵀρ))` (Eq. 15).
    pub theta: Vec<f64>,
    /// `Xᵀθ` (reused by every screening test; computing it dominates the
    /// screening cost so it is built once from `Xᵀρ`).
    pub xt_theta: Vec<f64>,
    /// `Ω^D(Xᵀρ)` — the dual norm of the unscaled correlation vector.
    pub dual_norm_xt_rho: f64,
    /// Primal objective at the current `β`.
    pub primal: f64,
    /// Dual objective at `θ`.
    pub dual: f64,
    /// Duality gap `P(β) − D(θ)` (clamped at 0 against round-off).
    pub gap: f64,
    /// GAP safe radius `sqrt(2·gap/λ²)` (Theorem 2).
    pub radius: f64,
}

impl DualSnapshot {
    /// Build the snapshot from the current iterate.
    ///
    /// `residual` must equal `y − Xβ`. Cost: one `Xᵀρ` product (`O(np)`)
    /// plus `O(p)` dual-norm work.
    pub fn compute<D: Design>(
        pb: &SglProblem<D>,
        beta: &[f64],
        residual: &[f64],
        lambda: f64,
    ) -> Self {
        Self::compute_ctx(pb, beta, residual, lambda, &SweepCtx::serial())
    }

    /// [`compute`](Self::compute) with the `Xᵀρ` product and the per-group
    /// dual norm fanned over a [`SweepCtx`] crew — per-column dots and
    /// per-group ε-norms are independent, so the parallel snapshot is
    /// bit-identical to the serial one.
    pub fn compute_ctx<D: Design>(
        pb: &SglProblem<D>,
        beta: &[f64],
        residual: &[f64],
        lambda: f64,
        ctx: &SweepCtx,
    ) -> Self {
        let mut xt_rho = vec![0.0; pb.p()];
        sweep::xt_full(ctx, pb, residual, &mut xt_rho);
        Self::compute_with_xt_rho_ctx(pb, beta, residual, &xt_rho, lambda, ctx)
    }

    /// Variant for callers that already hold `Xᵀρ` (the XLA engine and the
    /// perf-tuned CD loop reuse buffers).
    pub fn compute_with_xt_rho<D: Design>(
        pb: &SglProblem<D>,
        beta: &[f64],
        residual: &[f64],
        xt_rho: &[f64],
        lambda: f64,
    ) -> Self {
        Self::compute_with_xt_rho_ctx(pb, beta, residual, xt_rho, lambda, &SweepCtx::serial())
    }

    /// [`compute_with_xt_rho`](Self::compute_with_xt_rho), dual norm on
    /// the sweep crew.
    pub fn compute_with_xt_rho_ctx<D: Design>(
        pb: &SglProblem<D>,
        beta: &[f64],
        residual: &[f64],
        xt_rho: &[f64],
        lambda: f64,
        ctx: &SweepCtx,
    ) -> Self {
        let dual_norm = sweep::omega_dual(ctx, xt_rho, &pb.groups, pb.tau, &pb.weights);
        let scale = lambda.max(dual_norm);
        let theta: Vec<f64> = residual.iter().map(|r| r / scale).collect();
        let xt_theta: Vec<f64> = xt_rho.iter().map(|v| v / scale).collect();
        let primal = primal_value(pb, beta, residual, lambda);
        let dual = dual_value(&pb.y, &theta, lambda);
        let gap = (primal - dual).max(0.0);
        // The radius uses a *floored* gap: near convergence the computed
        // P - D can round to (or below) zero while the true gap is at the
        // rounding scale of the objectives; a radius-0 sphere would then
        // unsafely screen boundary-active groups (where Thm. 1 holds with
        // equality). The floor is the cancellation error scale of P - D.
        let float_floor = 16.0 * f64::EPSILON * (primal.abs() + dual.abs());
        let radius = (2.0 * gap.max(float_floor)).sqrt() / lambda;
        DualSnapshot { theta, xt_theta, dual_norm_xt_rho: dual_norm, primal, dual, gap, radius }
    }

    /// `‖θ − y/λ‖` — needed by the static/dynamic/DST3 sphere radii.
    pub fn dist_to_y_over_lambda(&self, y: &[f64], lambda: f64) -> f64 {
        let d: f64 = self
            .theta
            .iter()
            .zip(y)
            .map(|(t, yi)| {
                let d = t - yi / lambda;
                d * d
            })
            .sum();
        d.sqrt()
    }
}

/// Convenience: duality gap for given `β` (recomputes the residual).
pub fn duality_gap<D: Design>(pb: &SglProblem<D>, beta: &[f64], lambda: f64) -> f64 {
    let xb = pb.x.matvec(beta);
    let residual: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    DualSnapshot::compute(pb, beta, &residual, lambda).gap
}

/// Sanity helper used across tests: `‖y − Xβ‖` from scratch.
pub fn residual_norm<D: Design>(pb: &SglProblem<D>, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    l2_norm(&r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::norms::sgl::{in_dual_unit_ball, omega_dual};
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn random_problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[3, 2, 3]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(12, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.4)
    }

    #[test]
    fn dual_point_is_feasible() {
        let pb = random_problem(5);
        let mut rng = Pcg::seeded(99);
        for _ in 0..20 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.1).collect();
            let xb = pb.x.matvec(&beta);
            let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
            let lambda = rng.uniform_in(0.1, 2.0) * pb.lambda_max();
            let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
            let xt_theta = pb.x.tmatvec(&snap.theta);
            assert!(
                in_dual_unit_ball(&xt_theta, &pb.groups, pb.tau, &pb.weights, 1e-9),
                "theta must be dual feasible"
            );
            assert!(
                omega_dual(&xt_theta, &pb.groups, pb.tau, &pb.weights) <= 1.0 + 1e-9
            );
        }
    }

    #[test]
    fn xt_theta_is_consistent() {
        let pb = random_problem(6);
        let beta = vec![0.05; pb.p()];
        let xb = pb.x.matvec(&beta);
        let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let snap = DualSnapshot::compute(&pb, &beta, &rho, 0.7 * pb.lambda_max());
        let explicit = pb.x.tmatvec(&snap.theta);
        for (a, b) in snap.xt_theta.iter().zip(&explicit) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn weak_duality_nonneg_gap() {
        let pb = random_problem(7);
        let mut rng = Pcg::seeded(123);
        for _ in 0..30 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal()).collect();
            let lambda = rng.uniform_in(0.05, 1.5) * pb.lambda_max();
            let gap = duality_gap(&pb, &beta, lambda);
            assert!(gap >= 0.0);
        }
    }

    #[test]
    fn gap_zero_at_trivial_optimum() {
        // For lambda >= lambda_max, beta = 0 is optimal and theta = y/lmax
        // ... more precisely theta = y / max(lambda, Omega^D(X^T y)).
        let pb = random_problem(8);
        let lmax = pb.lambda_max();
        let beta = vec![0.0; pb.p()];
        let gap = duality_gap(&pb, &beta, 1.5 * lmax);
        assert!(gap < 1e-10, "gap={gap}");
        // Exactly at lambda_max the same holds.
        let gap_at = duality_gap(&pb, &beta, lmax);
        assert!(gap_at < 1e-10, "gap={gap_at}");
    }

    #[test]
    fn radius_formula() {
        let pb = random_problem(9);
        let beta = vec![0.01; pb.p()];
        let xb = pb.x.matvec(&beta);
        let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let lambda = 0.5 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
        assert!((snap.radius - (2.0 * snap.gap).sqrt() / lambda).abs() < 1e-14);
        assert!((snap.gap - (snap.primal - snap.dual)).abs() < 1e-12);
    }

    #[test]
    fn safe_ball_contains_dual_optimum() {
        // Theorem 2 smoke test: solve crudely by many ISTA steps, then check
        // the GAP ball built from an *early* iterate contains the late theta.
        let pb = random_problem(10);
        let lambda = 0.3 * pb.lambda_max();
        // crude proximal gradient with global step 1/L, L = sum Lg
        let l_total: f64 = pb.lipschitz.iter().sum::<f64>();
        let mut beta = vec![0.0; pb.p()];
        let mut snap_early = None;
        let mut last_snap = None;
        for it in 0..4000 {
            let xb = pb.x.matvec(&beta);
            let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
            let grad = pb.x.tmatvec(&rho); // = -nabla f
            for j in 0..pb.p() {
                beta[j] += grad[j] / l_total;
            }
            // prox per group
            for (g, a, b) in pb.groups.iter() {
                let block = &mut beta[a..b];
                crate::norms::prox::sgl_prox_inplace(
                    block,
                    pb.tau * lambda / l_total,
                    (1.0 - pb.tau) * pb.weights[g] * lambda / l_total,
                );
            }
            if it == 10 {
                let xb = pb.x.matvec(&beta);
                let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
                snap_early = Some(DualSnapshot::compute(&pb, &beta, &rho, lambda));
            }
            if it == 3999 {
                let xb = pb.x.matvec(&beta);
                let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
                last_snap = Some(DualSnapshot::compute(&pb, &beta, &rho, lambda));
            }
        }
        let early = snap_early.unwrap();
        let late = last_snap.unwrap();
        assert!(late.gap < 1e-8, "late gap {}", late.gap);
        // theta_hat ~ late.theta; must lie in the early safe ball.
        let dist: f64 = early
            .theta
            .iter()
            .zip(&late.theta)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(
            dist <= early.radius + 1e-6,
            "dist {dist} > radius {}",
            early.radius
        );
    }
}
