//! Primal/dual objectives, dual-point construction by residual scaling
//! (paper Eq. 15), duality gap, and the GAP safe radius (Theorem 2) —
//! generic over the [`Datafit`]: the dual point is always the scaled
//! generalized residual `r = −∇f(Xβ)`, the gap pairs the datafit's loss
//! with its conjugate, and the radius uses the datafit's dual curvature
//! (see the safety contract in [`crate::solver::datafit`]).

use super::datafit::{Datafit, StateRef};
use super::problem::SglProblem;
use super::sweep::{self, SweepCtx};
use crate::linalg::ops::l2_norm;
use crate::linalg::simd;
use crate::linalg::Design;
use crate::norms::block::{omega_rows, row_norms};

/// Primal objective `P_{λ,τ,w}(β) = f(β) + λΩ(β)` given the residual
/// `ρ = y − Xβ` (kept up to date by the solvers; never recomputed here).
///
/// Legacy residual-slice entry point: only valid for datafits whose
/// maintained state *is* the residual (quadratic); use
/// [`primal_value_state`] with the datafit's `main` vector otherwise.
pub fn primal_value<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    beta: &[f64],
    residual: &[f64],
    lambda: f64,
) -> f64 {
    assert!(pb.datafit.state_is_residual(), "residual-slice primal needs a residual-state datafit");
    primal_value_state(pb, beta, residual, lambda)
}

/// Primal objective from the datafit's maintained state vector
/// ([`crate::solver::datafit::FitState::main`]: the residual for
/// quadratic, the linear predictor for logistic).
pub fn primal_value_state<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    beta: &[f64],
    main: &[f64],
    lambda: f64,
) -> f64 {
    // `omega_rows` is the scalar `Ω` bit-for-bit at q = 1 and the row-norm
    // multi-task penalty otherwise (β is feature-major, `p · q` entries).
    let pen = omega_rows(beta, pb.datafit.tasks(), &pb.groups, pb.tau, &pb.weights);
    pb.datafit.loss(&pb.y, main, beta) + lambda * pen
}

/// Quadratic dual objective `D_λ(θ) = ½‖y‖² − λ²/2 ‖θ − y/λ‖²` (Eq. 6).
/// Kept as a free function — it is the least-squares conjugate that
/// [`crate::solver::datafit::Quadratic`] delegates to, and several tests
/// pin its exact arithmetic.
pub fn dual_value(y: &[f64], theta: &[f64], lambda: f64) -> f64 {
    debug_assert_eq!(y.len(), theta.len());
    // Policy-dispatched reductions: the scalar branches are the original
    // sequential fold / unrolled dot, bit-for-bit.
    let dist_sq = simd::dist_sq_scaled(y, theta, lambda);
    0.5 * simd::sq_norm(y) - 0.5 * lambda * lambda * dist_sq
}

/// A dual-feasible point built from the current generalized residual plus
/// everything the screening rules need alongside it.
#[derive(Clone, Debug)]
pub struct DualSnapshot {
    /// Dual feasible `θ = r / max(λ, Ω^D(Xᵀr))` (Eq. 15), `r` the
    /// generalized residual (`y − Xβ` quadratic, `y − σ(Xβ)` logistic).
    pub theta: Vec<f64>,
    /// `Xᵀθ` (reused by every screening test; computing it dominates the
    /// screening cost so it is built once from `Xᵀr`), including the
    /// datafit's ridge adjustment when present.
    pub xt_theta: Vec<f64>,
    /// `Ω^D(Xᵀr)` — the dual norm of the unscaled (adjusted) correlation
    /// vector.
    pub dual_norm_xt_rho: f64,
    /// Squared norm of the implicit ridge-block coordinates of `θ`
    /// (elastic-net quadratic only; `0` otherwise). Carried so sequential
    /// screening can re-evaluate the dual at later, smaller λ without the
    /// original `β`.
    pub theta_aug_sq: f64,
    /// Primal objective at the current `β`.
    pub primal: f64,
    /// Dual objective at `θ`.
    pub dual: f64,
    /// Duality gap `P(β) − D(θ)` (clamped at 0 against round-off).
    pub gap: f64,
    /// GAP safe radius `sqrt(2·c·gap)/λ` (Theorem 2; `c` the datafit
    /// curvature — 1 for quadratic, ¼ for logistic).
    pub radius: f64,
}

impl DualSnapshot {
    /// Build the snapshot from the current iterate.
    ///
    /// Legacy residual-slice entry point (`residual` must equal `y − Xβ`):
    /// only valid for residual-state datafits; generic solvers use
    /// [`compute_state_ctx`](Self::compute_state_ctx). Cost: one `Xᵀρ`
    /// product (`O(np)`) plus `O(p)` dual-norm work.
    pub fn compute<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        residual: &[f64],
        lambda: f64,
    ) -> Self {
        Self::compute_ctx(pb, beta, residual, lambda, &SweepCtx::serial())
    }

    /// [`compute`](Self::compute) with the `Xᵀρ` product and the per-group
    /// dual norm fanned over a [`SweepCtx`] crew — per-column dots and
    /// per-group ε-norms are independent, so the parallel snapshot is
    /// bit-identical to the serial one.
    pub fn compute_ctx<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        residual: &[f64],
        lambda: f64,
        ctx: &SweepCtx,
    ) -> Self {
        assert!(pb.datafit.state_is_residual(), "residual-slice snapshot needs a residual-state datafit");
        Self::compute_state_ctx(pb, beta, StateRef { main: residual, resid: residual }, lambda, ctx)
    }

    /// Snapshot from a full datafit state (serial convenience).
    pub fn compute_state<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        state: StateRef<'_>,
        lambda: f64,
    ) -> Self {
        Self::compute_state_ctx(pb, beta, state, lambda, &SweepCtx::serial())
    }

    /// Snapshot from a full datafit state: the datafit-generic engine
    /// behind every other constructor.
    pub fn compute_state_ctx<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        state: StateRef<'_>,
        lambda: f64,
        ctx: &SweepCtx,
    ) -> Self {
        let mut xt_rho = vec![0.0; pb.p() * pb.datafit.tasks()];
        sweep::xt_full(ctx, pb, state.resid, &mut xt_rho);
        Self::compute_state_with_xt_rho_ctx(pb, beta, state, &xt_rho, lambda, ctx)
    }

    /// Variant for callers that already hold `Xᵀρ` (the XLA engine and the
    /// perf-tuned CD loop reuse buffers). Legacy residual-slice form.
    pub fn compute_with_xt_rho<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        residual: &[f64],
        xt_rho: &[f64],
        lambda: f64,
    ) -> Self {
        Self::compute_with_xt_rho_ctx(pb, beta, residual, xt_rho, lambda, &SweepCtx::serial())
    }

    /// [`compute_with_xt_rho`](Self::compute_with_xt_rho), dual norm on
    /// the sweep crew.
    pub fn compute_with_xt_rho_ctx<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        residual: &[f64],
        xt_rho: &[f64],
        lambda: f64,
        ctx: &SweepCtx,
    ) -> Self {
        assert!(pb.datafit.state_is_residual(), "residual-slice snapshot needs a residual-state datafit");
        Self::compute_state_with_xt_rho_ctx(
            pb,
            beta,
            StateRef { main: residual, resid: residual },
            xt_rho,
            lambda,
            ctx,
        )
    }

    /// The datafit-generic snapshot core. `xt_rho` is the **raw**
    /// correlation `Xᵀ·state.resid`; any ridge adjustment is applied here.
    pub fn compute_state_with_xt_rho_ctx<D: Design, F: Datafit>(
        pb: &SglProblem<D, F>,
        beta: &[f64],
        state: StateRef<'_>,
        xt_rho: &[f64],
        lambda: f64,
        ctx: &SweepCtx,
    ) -> Self {
        let adjusted = pb.datafit.adjust_xt(xt_rho, beta);
        let q = pb.datafit.tasks();
        let dual_norm = if q == 1 {
            sweep::omega_dual(ctx, &adjusted, &pb.groups, pb.tau, &pb.weights)
        } else {
            // Multi-task dual norm: the scalar Ω^D on the p-vector of
            // feature row norms of the p × q correlation matrix.
            let scores = row_norms(&adjusted, q);
            sweep::omega_dual(ctx, &scores, &pb.groups, pb.tau, &pb.weights)
        };
        let scale = lambda.max(dual_norm);
        let theta: Vec<f64> = state.resid.iter().map(|r| r / scale).collect();
        let xt_theta: Vec<f64> = adjusted.iter().map(|v| v / scale).collect();
        let theta_aug_sq = pb.datafit.theta_aug_sq(beta, scale);
        let primal = primal_value_state(pb, beta, state.main, lambda);
        let dual = pb.datafit.dual_at(&pb.y, &theta, theta_aug_sq, lambda);
        let gap = (primal - dual).max(0.0);
        // The radius uses a *floored* gap: near convergence the computed
        // P - D can round to (or below) zero while the true gap is at the
        // rounding scale of the objectives; a radius-0 sphere would then
        // unsafely screen boundary-active groups (where Thm. 1 holds with
        // equality). The floor is the cancellation error scale of P - D.
        let float_floor = 16.0 * f64::EPSILON * (primal.abs() + dual.abs());
        let radius = (2.0 * pb.datafit.curvature() * gap.max(float_floor)).sqrt() / lambda;
        DualSnapshot {
            theta,
            xt_theta,
            dual_norm_xt_rho: dual_norm,
            theta_aug_sq,
            primal,
            dual,
            gap,
            radius,
        }
    }

    /// `‖θ − y/λ‖` — needed by the static/dynamic/DST3 sphere radii
    /// (quadratic-only rules).
    pub fn dist_to_y_over_lambda(&self, y: &[f64], lambda: f64) -> f64 {
        simd::dist_sq_scaled(y, &self.theta, lambda).sqrt()
    }
}

/// Convenience: duality gap for given `β` (recomputes the state from
/// scratch, any datafit).
pub fn duality_gap<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    beta: &[f64],
    lambda: f64,
) -> f64 {
    let state = pb.datafit.init_state(&pb.x, &pb.y, beta);
    DualSnapshot::compute_state(pb, beta, state.as_ref(), lambda).gap
}

/// Sanity helper used across tests: `‖y − Xβ‖` from scratch.
pub fn residual_norm<D: Design, F: Datafit>(pb: &SglProblem<D, F>, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    l2_norm(&r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::norms::sgl::{in_dual_unit_ball, omega_dual};
    use crate::solver::datafit::{Logistic, Quadratic};
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn random_problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[3, 2, 3]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(12, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.4)
    }

    fn random_logistic(seed: u64) -> SglProblem<Matrix, Logistic> {
        let groups = Groups::from_sizes(&[3, 2, 3]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(12, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| if rng.uniform() < 0.5 { 0.0 } else { 1.0 }).collect();
        let w = groups.sqrt_size_weights();
        SglProblem::with_datafit(x, y, groups, 0.4, w, Logistic)
    }

    #[test]
    fn dual_point_is_feasible() {
        let pb = random_problem(5);
        let mut rng = Pcg::seeded(99);
        for _ in 0..20 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.1).collect();
            let xb = pb.x.matvec(&beta);
            let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
            let lambda = rng.uniform_in(0.1, 2.0) * pb.lambda_max();
            let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
            let xt_theta = pb.x.tmatvec(&snap.theta);
            assert!(
                in_dual_unit_ball(&xt_theta, &pb.groups, pb.tau, &pb.weights, 1e-9),
                "theta must be dual feasible"
            );
            assert!(
                omega_dual(&xt_theta, &pb.groups, pb.tau, &pb.weights) <= 1.0 + 1e-9
            );
        }
    }

    #[test]
    fn xt_theta_is_consistent() {
        let pb = random_problem(6);
        let beta = vec![0.05; pb.p()];
        let xb = pb.x.matvec(&beta);
        let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let snap = DualSnapshot::compute(&pb, &beta, &rho, 0.7 * pb.lambda_max());
        let explicit = pb.x.tmatvec(&snap.theta);
        for (a, b) in snap.xt_theta.iter().zip(&explicit) {
            assert!((a - b).abs() < 1e-10);
        }
        assert_eq!(snap.theta_aug_sq, 0.0);
    }

    #[test]
    fn weak_duality_nonneg_gap() {
        let pb = random_problem(7);
        let mut rng = Pcg::seeded(123);
        for _ in 0..30 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal()).collect();
            let lambda = rng.uniform_in(0.05, 1.5) * pb.lambda_max();
            let gap = duality_gap(&pb, &beta, lambda);
            assert!(gap >= 0.0);
        }
    }

    #[test]
    fn gap_zero_at_trivial_optimum() {
        // For lambda >= lambda_max, beta = 0 is optimal and theta = y/lmax
        // ... more precisely theta = y / max(lambda, Omega^D(X^T y)).
        let pb = random_problem(8);
        let lmax = pb.lambda_max();
        let beta = vec![0.0; pb.p()];
        let gap = duality_gap(&pb, &beta, 1.5 * lmax);
        assert!(gap < 1e-10, "gap={gap}");
        // Exactly at lambda_max the same holds.
        let gap_at = duality_gap(&pb, &beta, lmax);
        assert!(gap_at < 1e-10, "gap={gap_at}");
    }

    #[test]
    fn radius_formula() {
        let pb = random_problem(9);
        let beta = vec![0.01; pb.p()];
        let xb = pb.x.matvec(&beta);
        let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let lambda = 0.5 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
        assert!((snap.radius - (2.0 * snap.gap).sqrt() / lambda).abs() < 1e-14);
        assert!((snap.gap - (snap.primal - snap.dual)).abs() < 1e-12);
    }

    #[test]
    fn safe_ball_contains_dual_optimum() {
        // Theorem 2 smoke test: solve crudely by many ISTA steps, then check
        // the GAP ball built from an *early* iterate contains the late theta.
        let pb = random_problem(10);
        let lambda = 0.3 * pb.lambda_max();
        // crude proximal gradient with global step 1/L, L = sum Lg
        let l_total: f64 = pb.lipschitz.iter().sum::<f64>();
        let mut beta = vec![0.0; pb.p()];
        let mut snap_early = None;
        let mut last_snap = None;
        for it in 0..4000 {
            let xb = pb.x.matvec(&beta);
            let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
            let grad = pb.x.tmatvec(&rho); // = -nabla f
            for j in 0..pb.p() {
                beta[j] += grad[j] / l_total;
            }
            // prox per group
            for (g, a, b) in pb.groups.iter() {
                let block = &mut beta[a..b];
                crate::norms::prox::sgl_prox_inplace(
                    block,
                    pb.tau * lambda / l_total,
                    (1.0 - pb.tau) * pb.weights[g] * lambda / l_total,
                );
            }
            if it == 10 {
                let xb = pb.x.matvec(&beta);
                let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
                snap_early = Some(DualSnapshot::compute(&pb, &beta, &rho, lambda));
            }
            if it == 3999 {
                let xb = pb.x.matvec(&beta);
                let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
                last_snap = Some(DualSnapshot::compute(&pb, &beta, &rho, lambda));
            }
        }
        let early = snap_early.unwrap();
        let late = last_snap.unwrap();
        assert!(late.gap < 1e-8, "late gap {}", late.gap);
        // theta_hat ~ late.theta; must lie in the early safe ball.
        let dist: f64 = early
            .theta
            .iter()
            .zip(&late.theta)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(
            dist <= early.radius + 1e-6,
            "dist {dist} > radius {}",
            early.radius
        );
    }

    #[test]
    fn ridge_snapshot_matches_explicit_row_stacking() {
        // The implicit elastic-net datafit must produce the same gap as
        // the historical [X; sqrt(mu) I] augmentation to rounding error.
        let pb = random_problem(14);
        let mu = 0.3;
        let en = SglProblem::with_datafit(
            pb.x.clone(),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
            pb.weights.clone(),
            Quadratic::with_ridge(mu),
        );
        let stacked_x = pb.x.vstack(&Matrix::scaled_identity(pb.p(), mu.sqrt()));
        let mut stacked_y = pb.y.clone();
        stacked_y.extend(std::iter::repeat(0.0).take(pb.p()));
        let aug = SglProblem::with_weights(
            stacked_x,
            stacked_y,
            pb.groups.clone(),
            pb.tau,
            pb.weights.clone(),
        );
        let mut rng = Pcg::seeded(321);
        let lambda = 0.4 * en.lambda_max();
        for _ in 0..5 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.2).collect();
            let g_en = duality_gap(&en, &beta, lambda);
            let g_aug = duality_gap(&aug, &beta, lambda);
            assert!(
                (g_en - g_aug).abs() < 1e-8 * (1.0 + g_aug.abs()),
                "implicit {g_en} vs stacked {g_aug}"
            );
        }
    }

    #[test]
    fn logistic_weak_duality_and_trivial_optimum() {
        let pb = random_logistic(31);
        let lmax = pb.lambda_max();
        assert!(lmax > 0.0);
        let zero = vec![0.0; pb.p()];
        let g0 = duality_gap(&pb, &zero, lmax);
        assert!(g0 < 1e-12, "gap at lambda_max should close exactly: {g0}");
        assert!(duality_gap(&pb, &zero, 1.5 * lmax) < 1e-12);
        let mut rng = Pcg::seeded(77);
        for _ in 0..20 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.5).collect();
            let lambda = rng.uniform_in(0.05, 1.2) * lmax;
            let gap = duality_gap(&pb, &beta, lambda);
            assert!(gap >= 0.0, "weak duality violated: {gap}");
        }
    }

    #[test]
    fn multitask_q1_snapshot_is_bitwise_scalar() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let pb = random_problem(41);
        let mt = SglProblem::with_datafit(
            pb.x.clone(),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
            pb.weights.clone(),
            MultiTaskQuadratic::new(1),
        );
        let mut rng = Pcg::seeded(55);
        for _ in 0..10 {
            let beta: Vec<f64> = (0..pb.p()).map(|_| rng.normal() * 0.2).collect();
            let lambda = rng.uniform_in(0.1, 1.2) * pb.lambda_max();
            let s1 = {
                let st = pb.datafit.init_state(&pb.x, &pb.y, &beta);
                DualSnapshot::compute_state(&pb, &beta, st.as_ref(), lambda)
            };
            let s2 = {
                let st = mt.datafit.init_state(&mt.x, &mt.y, &beta);
                DualSnapshot::compute_state(&mt, &beta, st.as_ref(), lambda)
            };
            assert_eq!(s1.primal.to_bits(), s2.primal.to_bits());
            assert_eq!(s1.dual.to_bits(), s2.dual.to_bits());
            assert_eq!(s1.gap.to_bits(), s2.gap.to_bits());
            assert_eq!(s1.radius.to_bits(), s2.radius.to_bits());
            assert_eq!(s1.dual_norm_xt_rho.to_bits(), s2.dual_norm_xt_rho.to_bits());
            for (a, b) in s1.theta.iter().zip(&s2.theta) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in s1.xt_theta.iter().zip(&s2.xt_theta) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn multitask_weak_duality_and_trivial_optimum() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let groups = Groups::from_sizes(&[3, 2, 3]);
        let q = 3;
        let mut rng = Pcg::seeded(61);
        let x = Matrix::from_fn(12, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..12 * q).map(|_| rng.normal()).collect();
        let w = groups.sqrt_size_weights();
        let pb = SglProblem::with_datafit(x, y, groups, 0.4, w, MultiTaskQuadratic::new(q));
        let lmax = pb.lambda_max();
        assert!(lmax > 0.0);
        // B = 0 is optimal at and above lambda_max: the gap closes.
        let zero = vec![0.0; pb.p() * q];
        assert!(duality_gap(&pb, &zero, lmax) < 1e-10);
        assert!(duality_gap(&pb, &zero, 1.5 * lmax) < 1e-10);
        for _ in 0..20 {
            let beta: Vec<f64> = (0..pb.p() * q).map(|_| rng.normal() * 0.3).collect();
            let lambda = rng.uniform_in(0.05, 1.2) * lmax;
            let gap = duality_gap(&pb, &beta, lambda);
            assert!(gap >= 0.0, "weak duality violated: {gap}");
        }
    }

    #[test]
    fn logistic_radius_uses_quarter_curvature() {
        let pb = random_logistic(32);
        let beta = vec![0.02; pb.p()];
        let state = pb.datafit.init_state(&pb.x, &pb.y, &beta);
        let lambda = 0.5 * pb.lambda_max();
        let snap = DualSnapshot::compute_state(&pb, &beta, state.as_ref(), lambda);
        assert!(snap.gap > 0.0);
        assert!((snap.radius - (0.5 * snap.gap).sqrt() / lambda).abs() < 1e-14);
    }
}
