//! λ-path engine with warm starts (paper §7.1).
//!
//! The experiments run Algorithm 2 over a non-increasing grid
//! `λ_t = λ_max · 10^{−δ t/(T−1)}`, warm-starting each solve from the
//! previous solution ("previous ε-solution" in Algorithm 2). The screening
//! rule instance is constructed **once per path** and carried across grid
//! points: per-problem precomputations (`Xᵀy`, `λ_max`, DST3 hyperplane)
//! amortize, and the sequential rule ([`crate::screening::RuleKind::GapSafeSeq`])
//! receives each solve's terminal dual point through
//! `ScreeningRule::on_solve_complete` so it can screen at epoch 0 of the
//! next grid point. Since all three native solvers drive the shared
//! active-set core, the path engine is solver-selectable
//! ([`solve_path_with`] + [`SolverKind`]) and backend-generic.
//!
//! [`PathBatch`] fans *independent* path solves (CV folds, rule/tolerance
//! comparison sweeps, multi-τ sweeps) across worker threads — within a
//! path the warm-started λ-loop is inherently sequential, so between-path
//! parallelism is embarrassingly clean. *Inside* each single-λ solve a
//! second, orthogonal axis exists since [`crate::solver::sweep`]: setting
//! `SolveOptions::sweep = "parallel"` parallelizes the per-epoch group
//! sweeps and per-check screening work over a per-solve worker crew —
//! the lever for single-path latency, composable with (but usually an
//! alternative to) the batch fan-out: a saturated `PathBatch` should keep
//! solves serial, a latency-critical single path should not.

use super::cd::{solve_with_rule, SolveOptions, SolveResult};
use super::datafit::{Datafit, Quadratic};
use super::duality::DualSnapshot;
use super::problem::{lambda_grid, SglProblem};
use super::SolverKind;
use crate::linalg::{Design, Matrix};
use crate::screening::{make_rule, RuleKind, ScreeningRule, Sphere};
use crate::util::pool::{parallel_map, resolve_threads};
use crate::util::timer::Stopwatch;
use crate::util::trace;
use std::sync::Arc;

/// Path configuration (paper defaults: `δ = 3`, `T = 100`).
#[derive(Clone, Debug)]
pub struct PathOptions {
    pub delta: f64,
    pub t_count: usize,
    pub solve: SolveOptions,
}

impl Default for PathOptions {
    fn default() -> Self {
        PathOptions { delta: 3.0, t_count: 100, solve: SolveOptions::default() }
    }
}

/// Result of a whole-path solve.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub lambdas: Vec<f64>,
    pub results: Vec<SolveResult>,
    /// Total wall-clock for the path (the Fig. 2c / 3b measurement).
    pub total_s: f64,
}

impl PathResult {
    /// Fraction of features active (not screened) per λ at the final check.
    pub fn active_feature_fractions(&self, p: usize) -> Vec<f64> {
        self.results.iter().map(|r| r.active.n_active_features() as f64 / p as f64).collect()
    }

    /// Fraction of groups active per λ.
    pub fn active_group_fractions(&self, n_groups: usize) -> Vec<f64> {
        self.results
            .iter()
            .map(|r| r.active.n_active_groups() as f64 / n_groups as f64)
            .collect()
    }

    /// Total epochs across the path.
    pub fn total_epochs(&self) -> usize {
        self.results.iter().map(|r| r.epochs).sum()
    }

    pub fn all_converged(&self) -> bool {
        self.results.iter().all(|r| r.converged)
    }
}

/// Solve the full path with warm starts (CD inner solver).
pub fn solve_path<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    opts: &PathOptions,
) -> PathResult {
    let lambda_max = pb.lambda_max();
    let lambdas = lambda_grid(lambda_max, opts.delta, opts.t_count);
    solve_path_on_grid(pb, &lambdas, opts)
}

/// Solve on an explicit λ grid with the CD inner solver (must be
/// non-increasing for warm starts to make sense; this is asserted).
pub fn solve_path_on_grid<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambdas: &[f64],
    opts: &PathOptions,
) -> PathResult {
    solve_path_with(pb, lambdas, opts, SolverKind::Cd)
}

/// Solve an explicit non-increasing λ grid with the chosen inner solver.
/// One rule instance is built per path and carried across grid points —
/// with `GapSafeSeq` this is what makes epoch-0 screening fire for CD,
/// ISTA and FISTA alike.
pub fn solve_path_with<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambdas: &[f64],
    opts: &PathOptions,
    solver: SolverKind,
) -> PathResult {
    solve_path_with_handoff(pb, lambdas, opts, solver, None).0
}

/// Terminal state carried across a λ-range boundary: the warm-start
/// coefficients plus the dual point the sequential rule screens from.
/// Produced by [`solve_path_with_handoff`] for the λ-range *before* a
/// boundary and consumed by the range after it, so a path split into
/// contiguous shards ([`crate::coordinator::shard`]) behaves exactly like
/// the uninterrupted engine: the warm start and the `GapSafeSeq` epoch-0
/// screening both survive the cut.
#[derive(Clone, Debug)]
pub struct DualHandoff {
    /// λ at which the carried point was produced (must be ≥ the first λ
    /// of the resumed grid).
    pub lambda: f64,
    /// Terminal primal iterate — the next range's warm start.
    pub beta: Vec<f64>,
    /// Terminal dual snapshot — replayed into the next range's rule via
    /// [`ScreeningRule::on_solve_complete`].
    pub snap: DualSnapshot,
}

/// Wraps the real rule to record the latest terminal dual point flowing
/// through `on_solve_complete`, so the path engine can export it as a
/// [`DualHandoff`] without changing any solver signature.
struct CaptureRule<D: Design, F: Datafit> {
    inner: Box<dyn ScreeningRule<D, F>>,
    last: Option<(f64, DualSnapshot)>,
}

impl<D: Design, F: Datafit> ScreeningRule<D, F> for CaptureRule<D, F> {
    fn kind(&self) -> RuleKind {
        self.inner.kind()
    }

    fn sphere(
        &mut self,
        pb: &SglProblem<D, F>,
        lambda: f64,
        snap: &DualSnapshot,
    ) -> Option<Sphere> {
        self.inner.sphere(pb, lambda, snap)
    }

    fn on_solve_complete(&mut self, pb: &SglProblem<D, F>, lambda: f64, snap: &DualSnapshot) {
        self.last = Some((lambda, snap.clone()));
        self.inner.on_solve_complete(pb, lambda, snap);
    }
}

/// [`solve_path_with`] plus resumption: an incoming [`DualHandoff`] seeds
/// the warm start and is replayed into the freshly built rule through
/// `on_solve_complete` — for `GapSafeSeq` that is its entire cross-λ state,
/// and every other rule derives its state from `pb` alone, so resuming is
/// bit-identical to never having stopped. Returns the path result together
/// with this range's outgoing handoff (`None` only for an empty grid with
/// no incoming handoff).
pub fn solve_path_with_handoff<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambdas: &[f64],
    opts: &PathOptions,
    solver: SolverKind,
    handoff: Option<&DualHandoff>,
) -> (PathResult, Option<DualHandoff>) {
    for w in lambdas.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-12), "lambda grid must be non-increasing");
    }
    let sw = Stopwatch::start();
    let _path_span = trace::span_with("solve_path", || {
        vec![
            ("grid", lambdas.len().into()),
            ("solver", solver.name().into()),
            ("rule", opts.solve.rule.name().into()),
        ]
    });
    let mut rule = CaptureRule { inner: make_rule(opts.solve.rule, pb), last: None };
    let mut warm: Option<Vec<f64>> = None;
    if let Some(h) = handoff {
        assert_eq!(h.beta.len(), pb.p() * pb.tasks(), "handoff beta length mismatch");
        if let Some(&first) = lambdas.first() {
            assert!(
                first <= h.lambda * (1.0 + 1e-12),
                "handoff must come from a lambda preceding the grid"
            );
        }
        rule.on_solve_complete(pb, h.lambda, &h.snap);
        warm = Some(h.beta.clone());
    }
    let mut results = Vec::with_capacity(lambdas.len());
    for &lambda in lambdas {
        let res = match solver {
            SolverKind::Cd => {
                solve_with_rule(pb, lambda, warm.as_deref(), &opts.solve, &mut rule)
            }
            SolverKind::Ista => super::ista::solve_ista_with_rule(
                pb,
                lambda,
                warm.as_deref(),
                &opts.solve,
                &mut rule,
            ),
            SolverKind::Fista => super::fista::solve_fista_with_rule(
                pb,
                lambda,
                warm.as_deref(),
                &opts.solve,
                &mut rule,
            ),
        };
        warm = Some(res.beta.clone());
        results.push(res);
    }
    let out = match (rule.last, warm) {
        (Some((lambda, snap)), Some(beta)) => Some(DualHandoff { lambda, beta, snap }),
        _ => None,
    };
    (PathResult { lambdas: lambdas.to_vec(), results, total_s: sw.elapsed_s() }, out)
}

/// One independent λ-path solve inside a [`PathBatch`].
pub struct PathBatchJob<D: Design = Matrix, F: Datafit = Quadratic> {
    /// Problem instance. Shared via `Arc` so fan-outs over the same design
    /// (rule sweeps, tolerance sweeps) pay for a single copy of `X`.
    pub pb: Arc<SglProblem<D, F>>,
    /// Explicit non-increasing grid; `None` derives the geometric grid of
    /// `opts` from `pb.lambda_max()`.
    pub lambdas: Option<Vec<f64>>,
    pub opts: PathOptions,
    /// Solve at this `τ` instead of `pb.tau`. The τ-specific clone (τ does
    /// not affect any precomputation, see [`SglProblem::with_tau`]) is made
    /// *inside the worker*, so a τ-sweep over one `Arc`'d problem holds at
    /// most `threads` copies of the design at a time.
    pub tau_override: Option<f64>,
    /// Free-form tag for reports (e.g. `"gap_safe@1e-8"`, `"tau=0.4"`).
    pub label: String,
}

/// Batched path engine: fans independent warm-started path solves across
/// worker threads via [`parallel_map`]. Used by the CV grid (`solver::cv`),
/// the rule-comparison jobs (`coordinator::jobs`), and
/// `benches/bench_path_batch.rs`. Results are returned in job order, and
/// are bit-identical to running the jobs one after another — threading
/// never changes any solve's arithmetic, only the wall-clock.
pub struct PathBatch<D: Design = Matrix, F: Datafit = Quadratic> {
    jobs: Vec<PathBatchJob<D, F>>,
}

impl<D: Design, F: Datafit> Default for PathBatch<D, F> {
    fn default() -> Self {
        PathBatch { jobs: Vec::new() }
    }
}

impl<D: Design, F: Datafit> PathBatch<D, F> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, job: PathBatchJob<D, F>) {
        self.jobs.push(job);
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    pub fn jobs(&self) -> &[PathBatchJob<D, F>] {
        &self.jobs
    }

    /// Run every job on up to `threads` workers (1 = plain sequential
    /// loop, 0 = auto: the `SGL_THREADS`/available-parallelism default).
    /// Work is handed out dynamically, so heterogeneous job costs (tight
    /// vs loose tolerances, screening on vs off) balance well.
    pub fn run(&self, threads: usize) -> Vec<PathResult> {
        let threads = resolve_threads(threads);
        parallel_map(self.jobs.len(), threads, |i| {
            let job = &self.jobs[i];
            let tau_clone: Option<SglProblem<D, F>> = job
                .tau_override
                .filter(|&tau| tau != job.pb.tau)
                .map(|tau| job.pb.with_tau(tau));
            let pb: &SglProblem<D, F> = match &tau_clone {
                Some(p) => p,
                None => job.pb.as_ref(),
            };
            match &job.lambdas {
                Some(grid) => solve_path_on_grid(pb, grid, &job.opts),
                None => solve_path(pb, &job.opts),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::RuleKind;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn random_problem(seed: u64) -> SglProblem {
        let groups = Groups::uniform(6, 3);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(30, p, |_, _| rng.normal());
        let mut beta_true = vec![0.0; p];
        beta_true[0] = 2.0;
        beta_true[7] = -1.0;
        let xb = x.matvec(&beta_true);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.01 * rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.3)
    }

    #[test]
    fn path_solves_all_lambdas() {
        let pb = random_problem(1);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 10,
            solve: SolveOptions { tol: 1e-8, ..Default::default() },
        };
        let path = solve_path(&pb, &opts);
        assert_eq!(path.lambdas.len(), 10);
        assert!(path.all_converged());
        // First lambda is lambda_max: zero solution.
        assert!(path.results[0].beta.iter().all(|&b| b == 0.0));
        // Active fractions increase (weakly) as lambda decreases.
        let fr = path.active_feature_fractions(pb.p());
        assert!(fr[0] <= fr[fr.len() - 1] + 1e-12);
    }

    #[test]
    fn path_matches_single_solves() {
        let pb = random_problem(2);
        let opts = PathOptions {
            delta: 1.5,
            t_count: 5,
            solve: SolveOptions { tol: 1e-10, ..Default::default() },
        };
        let path = solve_path(&pb, &opts);
        for (i, &lambda) in path.lambdas.iter().enumerate() {
            let single = crate::solver::cd::solve(&pb, lambda, None, &opts.solve);
            for j in 0..pb.p() {
                assert!(
                    (path.results[i].beta[j] - single.beta[j]).abs() < 1e-5,
                    "lambda {i} feature {j}"
                );
            }
        }
    }

    #[test]
    fn rules_produce_same_path_objectives() {
        let pb = random_problem(3);
        for rule in [RuleKind::None, RuleKind::GapSafe] {
            let opts = PathOptions {
                delta: 2.0,
                t_count: 6,
                solve: SolveOptions { rule, tol: 1e-9, ..Default::default() },
            };
            let path = solve_path(&pb, &opts);
            assert!(path.all_converged(), "{rule:?}");
        }
    }

    #[test]
    #[should_panic(expected = "non-increasing")]
    fn increasing_grid_rejected() {
        let pb = random_problem(4);
        let opts = PathOptions::default();
        solve_path_on_grid(&pb, &[1.0, 2.0], &opts);
    }

    fn planted_problem(seed: u64) -> SglProblem {
        // A Fig. 2-style planted-sparse instance, scaled for test time.
        let cfg = crate::data::synthetic::SyntheticConfig {
            n: 60,
            n_groups: 40,
            group_size: 5,
            gamma1: 5,
            gamma2: 3,
            seed,
            ..Default::default()
        };
        let d = crate::data::synthetic::generate(&cfg);
        // Unit-norm y: objective-agreement budgets below are then absolute.
        let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt();
        let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
        SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2)
    }

    #[test]
    fn gap_safe_seq_screens_at_epoch_zero_of_warm_grid_points() {
        let pb = planted_problem(11);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 10,
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol: 1e-8,
                record_history: true,
                ..Default::default()
            },
        };
        let path = solve_path(&pb, &opts);
        assert!(path.all_converged());
        // From the second grid point on, the carried dual point must
        // eliminate a strictly positive number of features at the *first*
        // gap check (epoch 0), before any new epochs run.
        for (t, res) in path.results.iter().enumerate().skip(1) {
            let first = res.history.first().expect("history recorded");
            assert_eq!(first.epoch, 0, "t={t}");
            assert!(
                first.active_features < pb.p(),
                "t={t}: no feature screened at the first check \
                 ({} of {} active)",
                first.active_features,
                pb.p()
            );
        }
    }

    #[test]
    fn gap_safe_seq_matches_other_rules_objectives() {
        let pb = planted_problem(12);
        let objective = |lambda: f64, beta: &[f64]| {
            let xb = pb.x.matvec(beta);
            let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
            0.5 * r2
                + lambda
                    * crate::norms::sgl::omega(beta, &pb.groups, pb.tau, &pb.weights)
        };
        let opts = |rule| PathOptions {
            delta: 2.0,
            t_count: 8,
            solve: SolveOptions { rule, tol: 1e-12, record_history: false, ..Default::default() },
        };
        let base = solve_path(&pb, &opts(RuleKind::GapSafe));
        let seq = solve_path(&pb, &opts(RuleKind::GapSafeSeq));
        assert!(base.all_converged() && seq.all_converged());
        for (i, &lambda) in base.lambdas.iter().enumerate() {
            let a = objective(lambda, &base.results[i].beta);
            let b = objective(lambda, &seq.results[i].beta);
            assert!((a - b).abs() <= 1e-7, "lambda {i}: {a} vs {b}");
        }
    }

    #[test]
    fn ista_and_fista_paths_follow_the_sequential_rule() {
        // Solver symmetry: the carried dual point must produce the same
        // screened-path behavior whichever inner solver runs the grid.
        let pb = planted_problem(13);
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 5);
        let opts = PathOptions {
            delta: 1.0,
            t_count: lambdas.len(),
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol: 1e-8,
                max_epochs: 500_000,
                record_history: true,
                ..Default::default()
            },
        };
        for solver in [SolverKind::Ista, SolverKind::Fista] {
            let path = solve_path_with(&pb, &lambdas, &opts, solver);
            assert!(path.all_converged(), "{solver:?}");
            // Epoch-0 screening from the carried dual point fires for the
            // full-gradient solvers exactly as for CD.
            let mut screened_at_zero = 0usize;
            for res in path.results.iter().skip(1) {
                let first = res.history.first().expect("history recorded");
                assert_eq!(first.epoch, 0, "{solver:?}");
                screened_at_zero += pb.p() - first.active_features;
            }
            assert!(screened_at_zero > 0, "{solver:?}: carried dual never screened");
        }
    }

    #[test]
    fn batch_matches_sequential_loop_across_thread_counts() {
        let pb = Arc::new(random_problem(7));
        let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 2.0, 6);
        let mut batch = PathBatch::new();
        for rule in [RuleKind::None, RuleKind::GapSafe, RuleKind::GapSafeSeq] {
            for tol in [1e-6, 1e-9] {
                batch.push(PathBatchJob {
                    pb: pb.clone(),
                    lambdas: Some(lambdas.clone()),
                    opts: PathOptions {
                        delta: 2.0,
                        t_count: lambdas.len(),
                        solve: SolveOptions {
                            rule,
                            tol,
                            record_history: false,
                            ..Default::default()
                        },
                    },
                    tau_override: None,
                    label: format!("{}@{tol:.0e}", rule.name()),
                });
            }
        }
        assert_eq!(batch.len(), 6);
        let serial = batch.run(1);
        let parallel = batch.run(4);
        // Threading must not change any solve: bit-identical coefficients.
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.lambdas, b.lambdas);
            for (ra, rb) in a.results.iter().zip(&b.results) {
                assert_eq!(ra.beta, rb.beta);
                assert_eq!(ra.epochs, rb.epochs);
            }
        }
        // And each job equals the plain sequential engine run directly.
        for (job, got) in batch.jobs().iter().zip(&serial) {
            let expect = solve_path_on_grid(job.pb.as_ref(), &lambdas, &job.opts);
            for (ra, rb) in expect.results.iter().zip(&got.results) {
                assert_eq!(ra.beta, rb.beta, "{}", job.label);
            }
        }
    }

    #[test]
    fn handoff_resume_matches_uninterrupted_path() {
        // Same grid shape as gap_safe_seq_screens_at_epoch_zero…: adjacent
        // λ's are close enough that the carried dual always screens.
        let pb = planted_problem(11);
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 10);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 10,
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol: 1e-8,
                record_history: true,
                ..Default::default()
            },
        };
        let full = solve_path_with(&pb, &lambdas, &opts, SolverKind::Cd);
        let (head, h) =
            solve_path_with_handoff(&pb, &lambdas[..4], &opts, SolverKind::Cd, None);
        let h = h.expect("non-empty grid must yield a handoff");
        assert_eq!(h.lambda, lambdas[3]);
        assert_eq!(h.beta, head.results[3].beta);
        let (tail, tail_h) =
            solve_path_with_handoff(&pb, &lambdas[4..], &opts, SolverKind::Cd, Some(&h));
        assert!(tail_h.is_some());
        // Resuming from the handoff is bit-identical to never stopping.
        for (i, res) in head.results.iter().chain(tail.results.iter()).enumerate() {
            assert_eq!(res.beta, full.results[i].beta, "t={i}");
            assert_eq!(res.epochs, full.results[i].epochs, "t={i}");
        }
        // The carried dual point screens at epoch 0 of the first resumed
        // grid point, exactly as it would mid-path.
        let first = tail.results[0].history.first().expect("history recorded");
        assert_eq!(first.epoch, 0);
        assert!(first.active_features < pb.p());
    }

    #[test]
    #[should_panic(expected = "preceding the grid")]
    fn handoff_from_a_smaller_lambda_rejected() {
        let pb = planted_problem(15);
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 4);
        let opts = PathOptions { delta: 1.0, t_count: 4, ..Default::default() };
        let (_, h) =
            solve_path_with_handoff(&pb, &lambdas, &opts, SolverKind::Cd, None);
        // Re-running the same grid from its *terminal* handoff would hand
        // a dual point forward in λ: the engine must refuse.
        solve_path_with_handoff(&pb, &lambdas, &opts, SolverKind::Cd, h.as_ref());
    }

    #[test]
    fn multitask_path_warm_starts_and_hands_off() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let q = 2;
        let groups = Groups::uniform(4, 3);
        let p = groups.p();
        let n = 24;
        let mut rng = Pcg::seeded(31);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        let w = groups.sqrt_size_weights();
        let pb = SglProblem::with_datafit(x, y, groups, 0.3, w, MultiTaskQuadratic::new(q));
        let lambdas = lambda_grid(pb.lambda_max(), 1.5, 8);
        let opts = PathOptions {
            delta: 1.5,
            t_count: 8,
            solve: SolveOptions { tol: 1e-9, ..Default::default() },
        };
        let full = solve_path_with(&pb, &lambdas, &opts, SolverKind::Cd);
        assert!(full.all_converged());
        assert!(full.results[0].beta.iter().all(|&b| b == 0.0));
        assert_eq!(full.results[0].beta.len(), p * q);
        // Split the grid; resuming from the handoff is bit-identical.
        let (head, h) =
            solve_path_with_handoff(&pb, &lambdas[..3], &opts, SolverKind::Cd, None);
        let h = h.expect("handoff");
        assert_eq!(h.beta.len(), p * q);
        let (tail, _) =
            solve_path_with_handoff(&pb, &lambdas[3..], &opts, SolverKind::Cd, Some(&h));
        for (i, res) in head.results.iter().chain(tail.results.iter()).enumerate() {
            assert_eq!(res.beta, full.results[i].beta, "t={i}");
            assert_eq!(res.epochs, full.results[i].epochs, "t={i}");
        }
    }

    #[test]
    fn batch_derives_grid_when_absent() {
        let pb = Arc::new(random_problem(8));
        let mut batch = PathBatch::new();
        batch.push(PathBatchJob {
            pb: pb.clone(),
            lambdas: None,
            opts: PathOptions {
                delta: 1.5,
                t_count: 5,
                solve: SolveOptions { tol: 1e-8, record_history: false, ..Default::default() },
            },
            tau_override: None,
            label: "auto-grid".into(),
        });
        let out = batch.run(2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].lambdas.len(), 5);
        assert!(out[0].all_converged());
    }
}
