//! λ-path solver with warm starts (paper §7.1).
//!
//! The experiments run Algorithm 2 over a non-increasing grid
//! `λ_t = λ_max · 10^{−δ t/(T−1)}`, warm-starting each solve from the
//! previous solution ("previous ε-solution" in Algorithm 2). The screening
//! rule's per-problem precomputations (`Xᵀy`, `λ_max`, DST3 hyperplane) are
//! shared across the whole path.

use super::cd::{solve_with_rule, SolveOptions, SolveResult};
use super::problem::SglProblem;
use crate::screening::make_rule;
use crate::util::timer::Stopwatch;

/// Path configuration (paper defaults: `δ = 3`, `T = 100`).
#[derive(Clone, Debug)]
pub struct PathOptions {
    pub delta: f64,
    pub t_count: usize,
    pub solve: SolveOptions,
}

impl Default for PathOptions {
    fn default() -> Self {
        PathOptions { delta: 3.0, t_count: 100, solve: SolveOptions::default() }
    }
}

/// Result of a whole-path solve.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub lambdas: Vec<f64>,
    pub results: Vec<SolveResult>,
    /// Total wall-clock for the path (the Fig. 2c / 3b measurement).
    pub total_s: f64,
}

impl PathResult {
    /// Fraction of features active (not screened) per λ at the final check.
    pub fn active_feature_fractions(&self, p: usize) -> Vec<f64> {
        self.results.iter().map(|r| r.active.n_active_features() as f64 / p as f64).collect()
    }

    /// Fraction of groups active per λ.
    pub fn active_group_fractions(&self, n_groups: usize) -> Vec<f64> {
        self.results
            .iter()
            .map(|r| r.active.n_active_groups() as f64 / n_groups as f64)
            .collect()
    }

    /// Total epochs across the path.
    pub fn total_epochs(&self) -> usize {
        self.results.iter().map(|r| r.epochs).sum()
    }

    pub fn all_converged(&self) -> bool {
        self.results.iter().all(|r| r.converged)
    }
}

/// Solve the full path with warm starts.
pub fn solve_path(pb: &SglProblem, opts: &PathOptions) -> PathResult {
    let lambda_max = pb.lambda_max();
    let lambdas = SglProblem::lambda_grid(lambda_max, opts.delta, opts.t_count);
    solve_path_on_grid(pb, &lambdas, opts)
}

/// Solve on an explicit λ grid (must be non-increasing for warm starts to
/// make sense; this is asserted).
pub fn solve_path_on_grid(pb: &SglProblem, lambdas: &[f64], opts: &PathOptions) -> PathResult {
    for w in lambdas.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-12), "lambda grid must be non-increasing");
    }
    let sw = Stopwatch::start();
    let mut rule = make_rule(opts.solve.rule, pb);
    let mut results = Vec::with_capacity(lambdas.len());
    let mut warm: Option<Vec<f64>> = None;
    for &lambda in lambdas {
        let res = solve_with_rule(pb, lambda, warm.as_deref(), &opts.solve, rule.as_mut());
        warm = Some(res.beta.clone());
        results.push(res);
    }
    PathResult { lambdas: lambdas.to_vec(), results, total_s: sw.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::screening::RuleKind;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn random_problem(seed: u64) -> SglProblem {
        let groups = Groups::uniform(6, 3);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(30, p, |_, _| rng.normal());
        let mut beta_true = vec![0.0; p];
        beta_true[0] = 2.0;
        beta_true[7] = -1.0;
        let xb = x.matvec(&beta_true);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.01 * rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.3)
    }

    #[test]
    fn path_solves_all_lambdas() {
        let pb = random_problem(1);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 10,
            solve: SolveOptions { tol: 1e-8, ..Default::default() },
        };
        let path = solve_path(&pb, &opts);
        assert_eq!(path.lambdas.len(), 10);
        assert!(path.all_converged());
        // First lambda is lambda_max: zero solution.
        assert!(path.results[0].beta.iter().all(|&b| b == 0.0));
        // Active fractions increase (weakly) as lambda decreases.
        let fr = path.active_feature_fractions(pb.p());
        assert!(fr[0] <= fr[fr.len() - 1] + 1e-12);
    }

    #[test]
    fn path_matches_single_solves() {
        let pb = random_problem(2);
        let opts = PathOptions {
            delta: 1.5,
            t_count: 5,
            solve: SolveOptions { tol: 1e-10, ..Default::default() },
        };
        let path = solve_path(&pb, &opts);
        for (i, &lambda) in path.lambdas.iter().enumerate() {
            let single = crate::solver::cd::solve(&pb, lambda, None, &opts.solve);
            for j in 0..pb.p() {
                assert!(
                    (path.results[i].beta[j] - single.beta[j]).abs() < 1e-5,
                    "lambda {i} feature {j}"
                );
            }
        }
    }

    #[test]
    fn rules_produce_same_path_objectives() {
        let pb = random_problem(3);
        for rule in [RuleKind::None, RuleKind::GapSafe] {
            let opts = PathOptions {
                delta: 2.0,
                t_count: 6,
                solve: SolveOptions { rule, tol: 1e-9, ..Default::default() },
            };
            let path = solve_path(&pb, &opts);
            assert!(path.all_converged(), "{rule:?}");
        }
    }

    #[test]
    #[should_panic(expected = "non-increasing")]
    fn increasing_grid_rejected() {
        let pb = random_problem(4);
        let opts = PathOptions::default();
        solve_path_on_grid(&pb, &[1.0, 2.0], &opts);
    }
}
