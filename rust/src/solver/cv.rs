//! Training/test validation over the `(λ, τ)` grid — the protocol behind
//! Fig. 3a: split observations 50/50, solve the path to gap `1e-8` on the
//! training half for each `τ ∈ {0, 0.1, …, 1}`, and report held-out
//! prediction error; pick the best `(τ★, λ★)`.

use super::path::{PathBatch, PathBatchJob, PathOptions};
use super::problem::SglProblem;
use crate::linalg::Design;
use crate::solver::datafit::{Logistic, MultiTaskQuadratic};
use crate::solver::groups::Groups;
use crate::util::rng::Pcg;
use std::sync::Arc;

/// A train/test row split.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<usize>,
    pub test: Vec<usize>,
}

/// Random split with the given training fraction.
pub fn split_rows(n: usize, train_frac: f64, seed: u64) -> Split {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg::seeded(seed);
    rng.shuffle(&mut idx);
    let n_train = ((n as f64) * train_frac).round() as usize;
    let n_train = n_train.clamp(1, n - 1);
    let mut train = idx[..n_train].to_vec();
    let mut test = idx[n_train..].to_vec();
    train.sort_unstable();
    test.sort_unstable();
    Split { train, test }
}

/// Validation-curve output for one `τ`.
#[derive(Clone, Debug)]
pub struct TauCurve {
    pub tau: f64,
    pub lambdas: Vec<f64>,
    /// Held-out mean squared prediction error per λ.
    pub test_mse: Vec<f64>,
}

/// Full grid result (Fig. 3a data) plus the selected model.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub curves: Vec<TauCurve>,
    pub best_tau: f64,
    pub best_lambda: f64,
    pub best_mse: f64,
    /// Coefficients refit on the training half at `(τ★, λ★)`.
    pub best_beta: Vec<f64>,
}

/// Mean squared error of predictions `X β` against `y`.
pub fn prediction_mse<D: Design>(x: &D, y: &[f64], beta: &[f64]) -> f64 {
    let pred = x.matvec(beta);
    let n = y.len().max(1);
    y.iter().zip(&pred).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / n as f64
}

/// Held-out classification quality of a logistic fit at one λ.
#[derive(Clone, Copy, Debug)]
pub struct ClassificationScore {
    /// Mean binomial deviance `2/n Σ [softplus(x_iᵀβ) − y_i x_iᵀβ]` —
    /// twice the average negative log-likelihood, the standard logistic
    /// analogue of test MSE.
    pub deviance: f64,
    /// Fraction of held-out labels on the wrong side of `σ(x_iᵀβ) = ½`
    /// (equivalently `x_iᵀβ = 0`).
    pub error_rate: f64,
}

/// Score predictions `σ(X β)` against binary labels `y ∈ {0, 1}`. The
/// deviance goes through the overflow-safe softplus, so extreme margins
/// never produce `exp` overflow or `ln(0)`.
pub fn classification_score<D: Design>(
    x: &D,
    y: &[f64],
    beta: &[f64],
) -> ClassificationScore {
    let z = x.matvec(beta);
    let n = y.len().max(1) as f64;
    let mut nll = 0.0;
    let mut wrong = 0usize;
    for (yi, zi) in y.iter().zip(&z) {
        // softplus(z) = ln(1 + e^z), evaluated in the stable tail.
        let softplus =
            if *zi > 0.0 { zi + (-zi).exp().ln_1p() } else { zi.exp().ln_1p() };
        nll += softplus - yi * zi;
        if f64::from(*zi > 0.0) != *yi {
            wrong += 1;
        }
    }
    ClassificationScore { deviance: 2.0 * nll / n, error_rate: wrong as f64 / n }
}

/// Validation-curve output for one `τ` under the logistic datafit.
#[derive(Clone, Debug)]
pub struct TauCurveLogistic {
    pub tau: f64,
    pub lambdas: Vec<f64>,
    /// Held-out mean binomial deviance per λ.
    pub test_deviance: Vec<f64>,
    /// Held-out misclassification rate per λ.
    pub test_error: Vec<f64>,
}

/// Full grid result for logistic validation plus the selected model
/// (chosen by deviance — the proper scoring rule; the error rate rides
/// along for reporting).
#[derive(Clone, Debug)]
pub struct CvLogisticResult {
    pub curves: Vec<TauCurveLogistic>,
    pub best_tau: f64,
    pub best_lambda: f64,
    pub best_deviance: f64,
    pub best_error: f64,
    /// Coefficients refit on the training half at `(τ★, λ★)`.
    pub best_beta: Vec<f64>,
}

/// The τ-grid validation under sparse-group **logistic** regression:
/// identical protocol to [`validate_tau_grid`] (shared training-half
/// precomputation, one [`PathBatchJob`] per τ) with held-out deviance
/// and misclassification in place of MSE. `y` must hold `{0, 1}` labels.
pub fn validate_tau_grid_logistic<D: Design>(
    x: &D,
    y: &[f64],
    groups: &Groups,
    taus: &[f64],
    path_opts: &PathOptions,
    split: &Split,
    threads: usize,
) -> CvLogisticResult {
    assert!(!taus.is_empty(), "at least one tau required");
    assert!(
        y.iter().all(|&v| v == 0.0 || v == 1.0),
        "logistic validation needs labels in {{0, 1}}"
    );
    let x_train = x.select_rows(&split.train);
    let y_train: Vec<f64> = split.train.iter().map(|&i| y[i]).collect();
    let x_test = x.select_rows(&split.test);
    let y_test: Vec<f64> = split.test.iter().map(|&i| y[i]).collect();

    let weights = groups.sqrt_size_weights();
    let base = Arc::new(SglProblem::with_datafit(
        x_train,
        y_train,
        groups.clone(),
        taus[0],
        weights,
        Logistic,
    ));
    let mut batch = PathBatch::new();
    for &tau in taus {
        batch.push(PathBatchJob {
            pb: base.clone(),
            lambdas: None,
            opts: path_opts.clone(),
            tau_override: Some(tau),
            label: format!("tau={tau}"),
        });
    }
    let paths = batch.run(threads);

    let outputs: Vec<(TauCurveLogistic, Vec<Vec<f64>>)> = taus
        .iter()
        .zip(paths)
        .map(|(&tau, path)| {
            let scores: Vec<ClassificationScore> = path
                .results
                .iter()
                .map(|r| classification_score(&x_test, &y_test, &r.beta))
                .collect();
            let betas: Vec<Vec<f64>> = path.results.iter().map(|r| r.beta.clone()).collect();
            (
                TauCurveLogistic {
                    tau,
                    lambdas: path.lambdas,
                    test_deviance: scores.iter().map(|s| s.deviance).collect(),
                    test_error: scores.iter().map(|s| s.error_rate).collect(),
                },
                betas,
            )
        })
        .collect();

    let mut best = (0usize, 0usize, f64::INFINITY);
    for (ti, (curve, _)) in outputs.iter().enumerate() {
        for (li, &dev) in curve.test_deviance.iter().enumerate() {
            if dev < best.2 {
                best = (ti, li, dev);
            }
        }
    }
    let (bt, bl, bdev) = best;
    CvLogisticResult {
        best_tau: outputs[bt].0.tau,
        best_lambda: outputs[bt].0.lambdas[bl],
        best_deviance: bdev,
        best_error: outputs[bt].0.test_error[bl],
        best_beta: outputs[bt].1[bl].clone(),
        curves: outputs.into_iter().map(|(c, _)| c).collect(),
    }
}

/// Held-out mean squared Frobenius prediction error `‖Y − X B‖_F² / (n q)`
/// of a multi-task fit: `y` is the task-major response (length `n·q`),
/// `beta` the feature-major coefficient matrix (length `p·q`, see the
/// [datafit module docs](crate::solver::datafit)). Per-entry mean, so
/// `q = 1` computes exactly [`prediction_mse`].
pub fn multitask_frobenius_score<D: Design>(
    x: &D,
    y: &[f64],
    beta: &[f64],
    tasks: usize,
) -> f64 {
    let n = x.n_rows();
    assert!(tasks > 0, "at least one task required");
    assert_eq!(y.len(), n * tasks, "task-major response length");
    assert_eq!(beta.len() % tasks, 0, "feature-major coefficient length");
    let p = beta.len() / tasks;
    let mut col = vec![0.0; p];
    let mut sq = 0.0;
    for k in 0..tasks {
        for (j, c) in col.iter_mut().enumerate() {
            *c = beta[j * tasks + k];
        }
        let pred = x.matvec(&col);
        for (yi, pi) in y[k * n..(k + 1) * n].iter().zip(&pred) {
            sq += (yi - pi) * (yi - pi);
        }
    }
    sq / (n * tasks).max(1) as f64
}

/// Validation-curve output for one `τ` under the multi-task datafit.
#[derive(Clone, Debug)]
pub struct TauCurveMultiTask {
    pub tau: f64,
    pub lambdas: Vec<f64>,
    /// Held-out per-entry squared Frobenius error per λ.
    pub test_frobenius: Vec<f64>,
}

/// Full grid result for multi-task validation plus the selected model.
#[derive(Clone, Debug)]
pub struct CvMultiTaskResult {
    pub curves: Vec<TauCurveMultiTask>,
    pub best_tau: f64,
    pub best_lambda: f64,
    pub best_frobenius: f64,
    /// Feature-major coefficients refit on the training half at `(τ★, λ★)`.
    pub best_beta: Vec<f64>,
}

/// The τ-grid validation under **multi-task** sparse-group least squares:
/// identical protocol to [`validate_tau_grid`] (shared training-half
/// precomputation, one [`PathBatchJob`] per τ) scored by held-out
/// Frobenius error over all `q` response columns at once. `y` is the
/// task-major response of length `n·q`.
pub fn validate_tau_grid_multitask<D: Design>(
    x: &D,
    y: &[f64],
    groups: &Groups,
    tasks: usize,
    taus: &[f64],
    path_opts: &PathOptions,
    split: &Split,
    threads: usize,
) -> CvMultiTaskResult {
    assert!(!taus.is_empty(), "at least one tau required");
    assert!(tasks > 0, "at least one task required");
    let n = x.n_rows();
    assert_eq!(y.len(), n * tasks, "task-major response length");
    // Row selection must act per task block: task-major means every task's
    // column is a contiguous n-slice of `y`.
    let select = |rows: &[usize]| -> Vec<f64> {
        let mut out = Vec::with_capacity(rows.len() * tasks);
        for k in 0..tasks {
            out.extend(rows.iter().map(|&i| y[k * n + i]));
        }
        out
    };
    let x_train = x.select_rows(&split.train);
    let y_train = select(&split.train);
    let x_test = x.select_rows(&split.test);
    let y_test = select(&split.test);

    let weights = groups.sqrt_size_weights();
    let base = Arc::new(SglProblem::with_datafit(
        x_train,
        y_train,
        groups.clone(),
        taus[0],
        weights,
        MultiTaskQuadratic::new(tasks),
    ));
    let mut batch = PathBatch::new();
    for &tau in taus {
        batch.push(PathBatchJob {
            pb: base.clone(),
            lambdas: None,
            opts: path_opts.clone(),
            tau_override: Some(tau),
            label: format!("tau={tau}"),
        });
    }
    let paths = batch.run(threads);

    let outputs: Vec<(TauCurveMultiTask, Vec<Vec<f64>>)> = taus
        .iter()
        .zip(paths)
        .map(|(&tau, path)| {
            let frob: Vec<f64> = path
                .results
                .iter()
                .map(|r| multitask_frobenius_score(&x_test, &y_test, &r.beta, tasks))
                .collect();
            let betas: Vec<Vec<f64>> = path.results.iter().map(|r| r.beta.clone()).collect();
            (TauCurveMultiTask { tau, lambdas: path.lambdas, test_frobenius: frob }, betas)
        })
        .collect();

    let mut best = (0usize, 0usize, f64::INFINITY);
    for (ti, (curve, _)) in outputs.iter().enumerate() {
        for (li, &f) in curve.test_frobenius.iter().enumerate() {
            if f < best.2 {
                best = (ti, li, f);
            }
        }
    }
    let (bt, bl, bfrob) = best;
    CvMultiTaskResult {
        best_tau: outputs[bt].0.tau,
        best_lambda: outputs[bt].0.lambdas[bl],
        best_frobenius: bfrob,
        best_beta: outputs[bt].1[bl].clone(),
        curves: outputs.into_iter().map(|(c, _)| c).collect(),
    }
}

/// Run the τ-grid validation. `threads` parallelizes across τ values via
/// the batched path engine (each τ is one [`PathBatchJob`] on the training
/// half). The design-dependent precomputations (column norms, block
/// spectral norms) are τ-independent, so they are done **once** and shared
/// by every job through [`SglProblem::with_tau`] — previously each worker
/// re-ran the power iterations.
pub fn validate_tau_grid<D: Design>(
    x: &D,
    y: &[f64],
    groups: &Groups,
    taus: &[f64],
    path_opts: &PathOptions,
    split: &Split,
    threads: usize,
) -> CvResult {
    assert!(!taus.is_empty(), "at least one tau required");
    let x_train = x.select_rows(&split.train);
    let y_train: Vec<f64> = split.train.iter().map(|&i| y[i]).collect();
    let x_test = x.select_rows(&split.test);
    let y_test: Vec<f64> = split.test.iter().map(|&i| y[i]).collect();

    let base = Arc::new(SglProblem::new(x_train, y_train, groups.clone(), taus[0]));
    let mut batch = PathBatch::new();
    for &tau in taus {
        batch.push(PathBatchJob {
            pb: base.clone(),
            lambdas: None, // per-τ geometric grid from the job's λ_max
            opts: path_opts.clone(),
            tau_override: Some(tau),
            label: format!("tau={tau}"),
        });
    }
    let paths = batch.run(threads);

    let outputs: Vec<(TauCurve, Vec<Vec<f64>>)> = taus
        .iter()
        .zip(paths)
        .map(|(&tau, path)| {
            let mse: Vec<f64> = path
                .results
                .iter()
                .map(|r| prediction_mse(&x_test, &y_test, &r.beta))
                .collect();
            let betas: Vec<Vec<f64>> = path.results.iter().map(|r| r.beta.clone()).collect();
            (TauCurve { tau, lambdas: path.lambdas, test_mse: mse }, betas)
        })
        .collect();

    let mut best = (0usize, 0usize, f64::INFINITY);
    for (ti, (curve, _)) in outputs.iter().enumerate() {
        for (li, &mse) in curve.test_mse.iter().enumerate() {
            if mse < best.2 {
                best = (ti, li, mse);
            }
        }
    }
    let (bt, bl, bmse) = best;
    CvResult {
        best_tau: outputs[bt].0.tau,
        best_lambda: outputs[bt].0.lambdas[bl],
        best_mse: bmse,
        best_beta: outputs[bt].1[bl].clone(),
        curves: outputs.into_iter().map(|(c, _)| c).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::cd::SolveOptions;

    fn planted_data(seed: u64) -> (Matrix, Vec<f64>, Groups) {
        let groups = Groups::uniform(5, 3);
        let p = groups.p();
        let n = 60;
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let mut beta = vec![0.0; p];
        beta[0] = 2.0;
        beta[1] = 1.0;
        beta[6] = -1.5;
        let xb = x.matvec(&beta);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.05 * rng.normal()).collect();
        (x, y, groups)
    }

    #[test]
    fn split_partitions_rows() {
        let s = split_rows(20, 0.5, 1);
        assert_eq!(s.train.len(), 10);
        assert_eq!(s.test.len(), 10);
        let mut all: Vec<usize> = s.train.iter().chain(&s.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn split_extremes_clamped() {
        let s = split_rows(5, 0.0, 2);
        assert_eq!(s.train.len(), 1);
        let s = split_rows(5, 1.0, 3);
        assert_eq!(s.test.len(), 1);
    }

    #[test]
    fn validation_finds_interior_model() {
        let (x, y, groups) = planted_data(4);
        let split = split_rows(x.n_rows(), 0.5, 7);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 12,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        let cv = validate_tau_grid(&x, &y, &groups, &[0.2, 0.5, 0.8], &opts, &split, 2);
        assert_eq!(cv.curves.len(), 3);
        // Best MSE should beat the null model (predicting 0).
        let y_test: Vec<f64> = split.test.iter().map(|&i| y[i]).collect();
        let null_mse: f64 =
            y_test.iter().map(|v| v * v).sum::<f64>() / y_test.len() as f64;
        assert!(cv.best_mse < null_mse, "{} vs {null_mse}", cv.best_mse);
        assert!(cv.best_lambda > 0.0);
        // Error curve is U-ish: best not at the very first lambda.
        let best_curve = cv.curves.iter().find(|c| c.tau == cv.best_tau).unwrap();
        assert!(cv.best_mse <= best_curve.test_mse[0]);
    }

    fn planted_logistic_data(seed: u64) -> (Matrix, Vec<f64>, Groups) {
        let groups = Groups::uniform(5, 3);
        let p = groups.p();
        let n = 80;
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let mut beta = vec![0.0; p];
        beta[0] = 2.5;
        beta[1] = 1.5;
        beta[6] = -2.0;
        let z = x.matvec(&beta);
        let y: Vec<f64> =
            z.iter().map(|&zi| f64::from(rng.uniform() < 1.0 / (1.0 + (-zi).exp()))).collect();
        (x, y, groups)
    }

    #[test]
    fn logistic_validation_beats_the_null_model() {
        let (x, y, groups) = planted_logistic_data(8);
        let split = split_rows(x.n_rows(), 0.5, 3);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 12,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        let cv =
            validate_tau_grid_logistic(&x, &y, &groups, &[0.2, 0.5, 0.8], &opts, &split, 2);
        assert_eq!(cv.curves.len(), 3);
        // The null model (β = 0) scores deviance 2·ln 2 and the base-rate
        // error; a planted signal must beat the deviance and not exceed
        // coin-flip error.
        assert!(cv.best_deviance < 2.0 * std::f64::consts::LN_2, "{}", cv.best_deviance);
        assert!(cv.best_error < 0.5, "{}", cv.best_error);
        assert!(cv.best_lambda > 0.0);
        // Curves carry both metrics for every grid point.
        for c in &cv.curves {
            assert_eq!(c.test_deviance.len(), c.lambdas.len());
            assert_eq!(c.test_error.len(), c.lambdas.len());
        }
        assert!(!cv.best_beta.iter().all(|&b| b == 0.0), "selected model is null");
    }

    #[test]
    fn classification_score_handles_extreme_margins() {
        let x = Matrix::from_fn(2, 1, |i, _| if i == 0 { 1.0 } else { -1.0 });
        // Perfectly separated with a huge coefficient: the stable softplus
        // keeps the deviance finite (≈ 0) instead of overflowing.
        let s = classification_score(&x, &[1.0, 0.0], &[1e4]);
        assert!(s.deviance.is_finite());
        assert!(s.deviance < 1e-10, "{}", s.deviance);
        assert_eq!(s.error_rate, 0.0);
        // Both labels wrong under the flipped sign.
        let s = classification_score(&x, &[0.0, 1.0], &[1e4]);
        assert!(s.deviance.is_finite());
        assert_eq!(s.error_rate, 1.0);
    }

    #[test]
    fn prediction_mse_zero_for_exact_fit() {
        let (x, _, _) = planted_data(5);
        let beta = vec![0.5; x.n_cols()];
        let y = x.matvec(&beta);
        assert!(prediction_mse(&x, &y, &beta) < 1e-20);
    }

    /// Planted two-task data sharing a support: task-major response.
    fn planted_multitask_data(seed: u64) -> (Matrix, Vec<f64>, Groups, usize) {
        let groups = Groups::uniform(5, 3);
        let p = groups.p();
        let n = 60;
        let tasks = 2;
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        // Feature-major B with a shared row-sparse support.
        let mut b = vec![0.0; p * tasks];
        b[0] = 2.0; // (j=0, k=0)
        b[1] = -1.0; // (j=0, k=1)
        b[6 * tasks] = 1.5;
        b[6 * tasks + 1] = 2.5;
        let mut y = vec![0.0; n * tasks];
        for k in 0..tasks {
            let col: Vec<f64> = (0..p).map(|j| b[j * tasks + k]).collect();
            let xb = x.matvec(&col);
            for i in 0..n {
                y[k * n + i] = xb[i] + 0.05 * rng.normal();
            }
        }
        (x, y, groups, tasks)
    }

    #[test]
    fn multitask_validation_beats_the_null_model() {
        let (x, y, groups, tasks) = planted_multitask_data(13);
        let split = split_rows(x.n_rows(), 0.5, 5);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 12,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        let cv = validate_tau_grid_multitask(
            &x,
            &y,
            &groups,
            tasks,
            &[0.2, 0.5, 0.8],
            &opts,
            &split,
            2,
        );
        assert_eq!(cv.curves.len(), 3);
        // Null model (B = 0) scores the per-entry second moment of the
        // held-out responses; the planted signal must beat it.
        let n = x.n_rows();
        let mut null = 0.0;
        for k in 0..tasks {
            for &i in &split.test {
                null += y[k * n + i] * y[k * n + i];
            }
        }
        null /= (split.test.len() * tasks) as f64;
        assert!(cv.best_frobenius < null, "{} vs {null}", cv.best_frobenius);
        assert!(cv.best_lambda > 0.0);
        assert_eq!(cv.best_beta.len(), groups.p() * tasks);
        assert!(!cv.best_beta.iter().all(|&b| b == 0.0), "selected model is null");
        for c in &cv.curves {
            assert_eq!(c.test_frobenius.len(), c.lambdas.len());
        }
    }

    #[test]
    fn multitask_validation_at_one_task_matches_quadratic_cv() {
        // q = 1 is the degenerate case the datafit pins bit-identical to
        // plain quadratic, and the Frobenius score reduces to MSE — so the
        // whole validation protocol must agree exactly.
        let (x, y, groups) = planted_data(17);
        let split = split_rows(x.n_rows(), 0.5, 9);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 10,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        let taus = [0.3, 0.7];
        let q = validate_tau_grid(&x, &y, &groups, &taus, &opts, &split, 2);
        let mt = validate_tau_grid_multitask(&x, &y, &groups, 1, &taus, &opts, &split, 2);
        assert_eq!(mt.best_tau, q.best_tau);
        assert_eq!(mt.best_lambda, q.best_lambda);
        assert_eq!(mt.best_frobenius, q.best_mse);
        assert_eq!(mt.best_beta, q.best_beta);
    }
}
