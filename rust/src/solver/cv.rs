//! Training/test validation over the `(λ, τ)` grid — the protocol behind
//! Fig. 3a: split observations 50/50, solve the path to gap `1e-8` on the
//! training half for each `τ ∈ {0, 0.1, …, 1}`, and report held-out
//! prediction error; pick the best `(τ★, λ★)`.

use super::path::{PathBatch, PathBatchJob, PathOptions};
use super::problem::SglProblem;
use crate::linalg::Design;
use crate::solver::groups::Groups;
use crate::util::rng::Pcg;
use std::sync::Arc;

/// A train/test row split.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<usize>,
    pub test: Vec<usize>,
}

/// Random split with the given training fraction.
pub fn split_rows(n: usize, train_frac: f64, seed: u64) -> Split {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg::seeded(seed);
    rng.shuffle(&mut idx);
    let n_train = ((n as f64) * train_frac).round() as usize;
    let n_train = n_train.clamp(1, n - 1);
    let mut train = idx[..n_train].to_vec();
    let mut test = idx[n_train..].to_vec();
    train.sort_unstable();
    test.sort_unstable();
    Split { train, test }
}

/// Validation-curve output for one `τ`.
#[derive(Clone, Debug)]
pub struct TauCurve {
    pub tau: f64,
    pub lambdas: Vec<f64>,
    /// Held-out mean squared prediction error per λ.
    pub test_mse: Vec<f64>,
}

/// Full grid result (Fig. 3a data) plus the selected model.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub curves: Vec<TauCurve>,
    pub best_tau: f64,
    pub best_lambda: f64,
    pub best_mse: f64,
    /// Coefficients refit on the training half at `(τ★, λ★)`.
    pub best_beta: Vec<f64>,
}

/// Mean squared error of predictions `X β` against `y`.
pub fn prediction_mse<D: Design>(x: &D, y: &[f64], beta: &[f64]) -> f64 {
    let pred = x.matvec(beta);
    let n = y.len().max(1);
    y.iter().zip(&pred).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / n as f64
}

/// Run the τ-grid validation. `threads` parallelizes across τ values via
/// the batched path engine (each τ is one [`PathBatchJob`] on the training
/// half). The design-dependent precomputations (column norms, block
/// spectral norms) are τ-independent, so they are done **once** and shared
/// by every job through [`SglProblem::with_tau`] — previously each worker
/// re-ran the power iterations.
pub fn validate_tau_grid<D: Design>(
    x: &D,
    y: &[f64],
    groups: &Groups,
    taus: &[f64],
    path_opts: &PathOptions,
    split: &Split,
    threads: usize,
) -> CvResult {
    assert!(!taus.is_empty(), "at least one tau required");
    let x_train = x.select_rows(&split.train);
    let y_train: Vec<f64> = split.train.iter().map(|&i| y[i]).collect();
    let x_test = x.select_rows(&split.test);
    let y_test: Vec<f64> = split.test.iter().map(|&i| y[i]).collect();

    let base = Arc::new(SglProblem::new(x_train, y_train, groups.clone(), taus[0]));
    let mut batch = PathBatch::new();
    for &tau in taus {
        batch.push(PathBatchJob {
            pb: base.clone(),
            lambdas: None, // per-τ geometric grid from the job's λ_max
            opts: path_opts.clone(),
            tau_override: Some(tau),
            label: format!("tau={tau}"),
        });
    }
    let paths = batch.run(threads);

    let outputs: Vec<(TauCurve, Vec<Vec<f64>>)> = taus
        .iter()
        .zip(paths)
        .map(|(&tau, path)| {
            let mse: Vec<f64> = path
                .results
                .iter()
                .map(|r| prediction_mse(&x_test, &y_test, &r.beta))
                .collect();
            let betas: Vec<Vec<f64>> = path.results.iter().map(|r| r.beta.clone()).collect();
            (TauCurve { tau, lambdas: path.lambdas, test_mse: mse }, betas)
        })
        .collect();

    let mut best = (0usize, 0usize, f64::INFINITY);
    for (ti, (curve, _)) in outputs.iter().enumerate() {
        for (li, &mse) in curve.test_mse.iter().enumerate() {
            if mse < best.2 {
                best = (ti, li, mse);
            }
        }
    }
    let (bt, bl, bmse) = best;
    CvResult {
        best_tau: outputs[bt].0.tau,
        best_lambda: outputs[bt].0.lambdas[bl],
        best_mse: bmse,
        best_beta: outputs[bt].1[bl].clone(),
        curves: outputs.into_iter().map(|(c, _)| c).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::cd::SolveOptions;

    fn planted_data(seed: u64) -> (Matrix, Vec<f64>, Groups) {
        let groups = Groups::uniform(5, 3);
        let p = groups.p();
        let n = 60;
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let mut beta = vec![0.0; p];
        beta[0] = 2.0;
        beta[1] = 1.0;
        beta[6] = -1.5;
        let xb = x.matvec(&beta);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.05 * rng.normal()).collect();
        (x, y, groups)
    }

    #[test]
    fn split_partitions_rows() {
        let s = split_rows(20, 0.5, 1);
        assert_eq!(s.train.len(), 10);
        assert_eq!(s.test.len(), 10);
        let mut all: Vec<usize> = s.train.iter().chain(&s.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn split_extremes_clamped() {
        let s = split_rows(5, 0.0, 2);
        assert_eq!(s.train.len(), 1);
        let s = split_rows(5, 1.0, 3);
        assert_eq!(s.test.len(), 1);
    }

    #[test]
    fn validation_finds_interior_model() {
        let (x, y, groups) = planted_data(4);
        let split = split_rows(x.n_rows(), 0.5, 7);
        let opts = PathOptions {
            delta: 2.0,
            t_count: 12,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        let cv = validate_tau_grid(&x, &y, &groups, &[0.2, 0.5, 0.8], &opts, &split, 2);
        assert_eq!(cv.curves.len(), 3);
        // Best MSE should beat the null model (predicting 0).
        let y_test: Vec<f64> = split.test.iter().map(|&i| y[i]).collect();
        let null_mse: f64 =
            y_test.iter().map(|v| v * v).sum::<f64>() / y_test.len() as f64;
        assert!(cv.best_mse < null_mse, "{} vs {null_mse}", cv.best_mse);
        assert!(cv.best_lambda > 0.0);
        // Error curve is U-ish: best not at the very first lambda.
        let best_curve = cv.curves.iter().find(|c| c.tau == cv.best_tau).unwrap();
        assert!(cv.best_mse <= best_curve.test_mse[0]);
    }

    #[test]
    fn prediction_mse_zero_for_exact_fit() {
        let (x, _, _) = planted_data(5);
        let beta = vec![0.5; x.n_cols()];
        let y = x.matvec(&beta);
        assert!(prediction_mse(&x, &y, &beta) < 1e-20);
    }
}
