//! Sequential **strong rules** (Tibshirani et al., 2012) extended to the
//! Sparse-Group Lasso — the *unsafe* screening baseline the paper contrasts
//! with (§1, §7: unsafe rules may discard active variables, so they need a
//! KKT post-check/re-solve loop; TLFre-style sequential rules without one
//! can fail to converge).
//!
//! Heuristic (group level). Along a path `λ_prev → λ`, assuming the group
//! correlations are 1-Lipschitz in λ ("unit slope"), a group is *probably*
//! inactive at `λ` if
//!
//! ```text
//!   ‖S_{τ(2λ−λ_prev)}(X_gᵀρ_prev)‖ < (1−τ) w_g (2λ − λ_prev),
//! ```
//!
//! the SGL analogue of the lasso strong bound `|X_jᵀρ_prev| < 2λ − λ_prev`.
//! This can be wrong, so after solving the restricted subproblem we check
//! the discarded groups against the exact zero-block KKT condition
//! `‖S_{τλ}(X_gᵀρ)‖ ≤ λ(1−τ)w_g` and re-solve with any violators added
//! back, repeating until clean. The result is exact; only the *route* is
//! heuristic.

use super::cd::{solve, SolveOptions};
use super::groups::Groups;
use super::problem::SglProblem;
use crate::linalg::ops::l2_norm;
use crate::linalg::Design;
use crate::norms::prox::soft_threshold_vec;
use crate::util::timer::Stopwatch;

/// Statistics of a strong-rule path solve.
#[derive(Clone, Debug, Default)]
pub struct StrongStats {
    /// Total KKT violations encountered (groups wrongly discarded).
    pub violations: usize,
    /// Total subproblem solves (≥ number of λ values; > if violations).
    pub subsolves: usize,
    /// Sum over λ of the initially-kept group counts.
    pub kept_groups_initial: usize,
}

/// Result per λ of the strong-rule path.
#[derive(Clone, Debug)]
pub struct StrongResult {
    pub lambda: f64,
    pub beta: Vec<f64>,
    pub gap: f64,
    pub converged: bool,
    /// Groups in the final working set.
    pub working_groups: usize,
}

/// Which groups the strong rule keeps for `λ` given the previous residual
/// correlations `xt_rho_prev = Xᵀρ(λ_prev)`. Derived for the plain
/// least-squares dual, so the driver below is quadratic-only; the design
/// backend is generic (dense and CSC alike).
pub fn strong_keep_groups<D: Design>(
    pb: &SglProblem<D>,
    xt_rho_prev: &[f64],
    lambda_prev: f64,
    lambda: f64,
) -> Vec<bool> {
    debug_assert!(lambda <= lambda_prev);
    let thr = 2.0 * lambda - lambda_prev;
    let tau = pb.tau;
    pb.groups
        .iter()
        .map(|(g, a, b)| {
            if thr <= 0.0 {
                return true; // bound vacuous: keep everything
            }
            let st = soft_threshold_vec(&xt_rho_prev[a..b], tau * thr);
            l2_norm(&st) >= (1.0 - tau) * pb.weights[g] * thr
        })
        .collect()
}

/// Build the restricted subproblem over the kept groups. Returns the
/// subproblem and the kept group indices (for embedding solutions back).
/// Column extraction goes through [`Design::select_cols`], so the
/// restricted design stays in the backend's own format (packed dense,
/// pruned CSC).
fn subproblem<D: Design>(pb: &SglProblem<D>, keep: &[bool]) -> (SglProblem<D>, Vec<usize>) {
    let kept: Vec<usize> = (0..pb.n_groups()).filter(|&g| keep[g]).collect();
    let sizes: Vec<usize> = kept.iter().map(|&g| pb.groups.size(g)).collect();
    let mut cols = Vec::with_capacity(sizes.iter().sum());
    for &g in &kept {
        let (a, b) = pb.groups.bounds(g);
        cols.extend(a..b);
    }
    let x = pb.x.select_cols(&cols);
    let weights: Vec<f64> = kept.iter().map(|&g| pb.weights[g]).collect();
    let sub = SglProblem::with_weights(
        x,
        pb.y.clone(),
        Groups::from_sizes(&sizes),
        pb.tau,
        weights,
    );
    (sub, kept)
}

/// Embed a subproblem solution into the full coefficient vector.
fn embed<D: Design>(pb: &SglProblem<D>, kept: &[usize], sub_beta: &[f64]) -> Vec<f64> {
    let mut beta = vec![0.0; pb.p()];
    let mut col = 0;
    for &g in kept {
        let (a, b) = pb.groups.bounds(g);
        for j in a..b {
            beta[j] = sub_beta[col];
            col += 1;
        }
    }
    beta
}

/// Zero-block KKT check for the discarded groups; returns violators.
fn kkt_violations<D: Design>(
    pb: &SglProblem<D>,
    keep: &[bool],
    beta: &[f64],
    lambda: f64,
) -> Vec<usize> {
    let xb = pb.x.matvec(beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let mut out = Vec::new();
    for (g, a, b) in pb.groups.iter() {
        if keep[g] {
            continue;
        }
        let mut corr = vec![0.0; b - a];
        pb.x.tmatvec_block(a, b, &rho, &mut corr);
        let st = soft_threshold_vec(&corr, pb.tau * lambda);
        // Small slack: the subproblem is solved to finite tolerance.
        if l2_norm(&st) > lambda * (1.0 - pb.tau) * pb.weights[g] * (1.0 + 1e-8) + 1e-10 {
            out.push(g);
        }
    }
    out
}

/// Solve a non-increasing λ grid with sequential strong rules + KKT
/// recovery. Returns per-λ results, stats, and the total wall time.
pub fn solve_path_strong<D: Design>(
    pb: &SglProblem<D>,
    lambdas: &[f64],
    opts: &SolveOptions,
) -> (Vec<StrongResult>, StrongStats, f64) {
    let sw = Stopwatch::start();
    let mut stats = StrongStats::default();
    let mut results = Vec::with_capacity(lambdas.len());
    let mut beta_prev = vec![0.0; pb.p()];
    let mut lambda_prev = pb.lambda_max();
    for &lambda in lambdas {
        // Correlations at the previous solution.
        let xb = pb.x.matvec(&beta_prev);
        let rho_prev: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let xt_prev = pb.x.tmatvec(&rho_prev);
        let mut keep = strong_keep_groups(pb, &xt_prev, lambda_prev, lambda);
        // Never discard groups carrying warm-start signal.
        for (g, a, b) in pb.groups.iter() {
            if beta_prev[a..b].iter().any(|&v| v != 0.0) {
                keep[g] = true;
            }
        }
        stats.kept_groups_initial += keep.iter().filter(|&&k| k).count();

        let (beta, gap, converged) = loop {
            if keep.iter().all(|&k| !k) {
                // Empty working set: candidate solution is beta = 0.
                let beta_full = vec![0.0; pb.p()];
                let violators = kkt_violations(pb, &keep, &beta_full, lambda);
                if violators.is_empty() {
                    let gap = crate::solver::duality::duality_gap(pb, &beta_full, lambda);
                    break (beta_full, gap, true);
                }
                stats.violations += violators.len();
                for g in violators {
                    keep[g] = true;
                }
                continue;
            }
            let (sub, kept) = subproblem(pb, &keep);
            let warm: Vec<f64> = {
                let mut w = Vec::with_capacity(sub.p());
                for &g in &kept {
                    let (a, b) = pb.groups.bounds(g);
                    w.extend_from_slice(&beta_prev[a..b]);
                }
                w
            };
            let res = solve(&sub, lambda, Some(&warm), opts);
            stats.subsolves += 1;
            let beta_full = embed(pb, &kept, &res.beta);
            let violators = kkt_violations(pb, &keep, &beta_full, lambda);
            if violators.is_empty() {
                break (beta_full, res.gap, res.converged);
            }
            stats.violations += violators.len();
            for g in violators {
                keep[g] = true;
            }
        };
        results.push(StrongResult {
            lambda,
            beta: beta.clone(),
            gap,
            converged,
            working_groups: keep.iter().filter(|&&k| k).count(),
        });
        beta_prev = beta;
        lambda_prev = lambda;
    }
    (results, stats, sw.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::screening::RuleKind;
    use crate::solver::path::{solve_path_on_grid, PathOptions};

    fn problem(seed: u64) -> SglProblem {
        let cfg = SyntheticConfig {
            n: 50,
            n_groups: 30,
            group_size: 4,
            gamma1: 4,
            gamma2: 2,
            seed,
            ..Default::default()
        };
        let d = generate(&cfg);
        SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3)
    }

    #[test]
    fn strong_path_matches_exact_path() {
        let pb = problem(1);
        let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 2.0, 8);
        let opts = SolveOptions { tol: 1e-9, record_history: false, ..Default::default() };
        let (strong, stats, _) = solve_path_strong(&pb, &lambdas, &opts);
        let exact = solve_path_on_grid(
            &pb,
            &lambdas,
            &PathOptions { delta: 2.0, t_count: 8, solve: opts.clone() },
        );
        assert!(stats.subsolves >= lambdas.len());
        for (s, e) in strong.iter().zip(&exact.results) {
            assert!(s.converged);
            for j in 0..pb.p() {
                assert!(
                    (s.beta[j] - e.beta[j]).abs() < 5e-4,
                    "lambda={} j={j}: {} vs {}",
                    s.lambda,
                    s.beta[j],
                    e.beta[j]
                );
            }
        }
    }

    #[test]
    fn strong_rule_discards_aggressively() {
        // The point of strong rules: the working set is much smaller than
        // the full group count near lambda_max.
        let pb = problem(2);
        let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 1.0, 5);
        let opts = SolveOptions { tol: 1e-8, record_history: false, ..Default::default() };
        let (strong, stats, _) = solve_path_strong(&pb, &lambdas, &opts);
        let avg_kept = stats.kept_groups_initial as f64 / lambdas.len() as f64;
        assert!(
            avg_kept < pb.n_groups() as f64 * 0.8,
            "strong rule kept {avg_kept:.1} of {} groups on average",
            pb.n_groups()
        );
        assert!(strong.iter().all(|r| r.converged));
    }

    #[test]
    fn strong_path_on_csc_matches_dense() {
        // The driver is generic over the design backend: the same data as
        // CSC must walk the same keep/violation route and land on the same
        // solutions (both solved to tight tolerance).
        let pb = problem(5);
        let pb_csc = SglProblem::new(
            crate::linalg::CscMatrix::from_dense(&pb.x),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
        );
        let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 2.0, 6);
        let opts = SolveOptions { tol: 1e-9, record_history: false, ..Default::default() };
        let (dense, _, _) = solve_path_strong(&pb, &lambdas, &opts);
        let (sparse, _, _) = solve_path_strong(&pb_csc, &lambdas, &opts);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!(a.converged && b.converged);
            for j in 0..pb.p() {
                assert!(
                    (a.beta[j] - b.beta[j]).abs() < 5e-6,
                    "lambda={} j={j}",
                    a.lambda
                );
            }
        }
    }

    #[test]
    fn keep_mask_vacuous_when_threshold_nonpositive() {
        let pb = problem(3);
        let xt = pb.x.tmatvec(&pb.y);
        // lambda < lambda_prev/2 makes 2*lambda - lambda_prev <= 0.
        let keep = strong_keep_groups(&pb, &xt, 1.0, 0.4);
        assert!(keep.iter().all(|&k| k));
    }

    #[test]
    fn gap_safe_restricted_inside_strong_still_exact() {
        // Run the strong driver with GAP safe *inside* the subsolves — the
        // combination used in practice (working sets + safe rules).
        let pb = problem(4);
        let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 2.0, 6);
        let opts = SolveOptions {
            tol: 1e-9,
            rule: RuleKind::GapSafe,
            record_history: false,
            ..Default::default()
        };
        let (strong, _, _) = solve_path_strong(&pb, &lambdas, &opts);
        // Spot-check KKT at the last lambda.
        let last = strong.last().unwrap();
        let g = crate::solver::duality::duality_gap(&pb, &last.beta, last.lambda);
        let tol_abs = 1e-9 * pb.y.iter().map(|v| v * v).sum::<f64>();
        assert!(g <= 2.0 * tol_abs, "gap {g}");
    }
}
