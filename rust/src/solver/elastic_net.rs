//! Sparse-Group Lasso + Elastic-Net (paper App. D).
//!
//! The estimator `argmin ½‖y − Xβ‖² + λ₁Ω(β) + (λ₂/2)‖β‖²` is a plain SGL
//! problem on the augmented design
//!
//! ```text
//!   X̃ = [X; sqrt(λ₂) I_p] ∈ R^{(n+p)×p},   ỹ = [y; 0],
//! ```
//!
//! but the rows of `sqrt(λ₂) I_p` never need to exist: every quantity the
//! solvers and the GAP-safe machinery read off `X̃` factors through the
//! datafit ([`Quadratic::with_ridge`]) —
//!
//! - correlations: `X̃ᵀρ̃ = Xᵀρ − λ₂β` (the datafit's gradient correction),
//! - column norms / Lipschitz: `‖X̃_j‖² = ‖X_j‖² + λ₂` (folded at
//!   construction by [`SglProblem::with_datafit`]),
//! - dual augmentation: `θ̃` carries `λ₂‖β‖²/scale²` into the gap
//!   (`theta_aug_sq` on the snapshot).
//!
//! This keeps the design in its native backend — dense *or* CSC — instead
//! of row-stacking a dense identity (which destroyed sparsity and forced
//! the EN path dense-only).

use super::datafit::Quadratic;
use super::groups::Groups;
use super::problem::SglProblem;
use crate::linalg::Design;

/// Build the SGL+EN problem of Eq. (38) with the ℓ2 term carried
/// implicitly by the datafit (no row-stacking, any design backend).
pub fn elastic_net_problem<D: Design>(
    x: &D,
    y: &[f64],
    groups: Groups,
    tau: f64,
    lambda2: f64,
) -> SglProblem<D> {
    assert!(lambda2 >= 0.0, "lambda2 must be non-negative");
    let weights = groups.sqrt_size_weights();
    SglProblem::with_datafit(
        x.clone(),
        y.to_vec(),
        groups,
        tau,
        weights,
        Quadratic::with_ridge(lambda2),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, Matrix};
    use crate::screening::RuleKind;
    use crate::solver::cd::{solve, SolveOptions};
    use crate::util::rng::Pcg;

    fn data(seed: u64) -> (Matrix, Vec<f64>, Groups) {
        let groups = Groups::uniform(4, 3);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(20, p, |_, _| rng.normal());
        let mut beta = vec![0.0; p];
        beta[0] = 2.0;
        beta[5] = -1.0;
        let xb = x.matvec(&beta);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.02 * rng.normal()).collect();
        (x, y, groups)
    }

    #[test]
    fn lambda2_zero_recovers_plain_sgl() {
        let (x, y, groups) = data(1);
        let pb_plain = SglProblem::new(x.clone(), y.clone(), groups.clone(), 0.4);
        let pb_en = elastic_net_problem(&x, &y, groups, 0.4, 0.0);
        let lambda = 0.2 * pb_plain.lambda_max();
        let opts = SolveOptions { tol: 1e-10, ..Default::default() };
        let a = solve(&pb_plain, lambda, None, &opts);
        let b = solve(&pb_en, lambda, None, &opts);
        for j in 0..pb_plain.p() {
            assert!((a.beta[j] - b.beta[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn implicit_ridge_matches_explicit_row_stacking() {
        // The old formulation, built by hand: stack sqrt(lambda2)*I under X
        // and zeros under y, then solve as plain SGL. The implicit-datafit
        // problem must land on the same minimizer.
        let (x, y, groups) = data(5);
        let tau = 0.35;
        let lambda2 = 3.0;
        let p = x.n_cols();
        let x_aug = x.vstack(&Matrix::scaled_identity(p, lambda2.sqrt()));
        let mut y_aug = y.clone();
        y_aug.extend(std::iter::repeat(0.0).take(p));
        let pb_stacked = SglProblem::new(x_aug, y_aug, groups.clone(), tau);
        let pb_en = elastic_net_problem(&x, &y, groups, tau, lambda2);
        assert!((pb_stacked.lambda_max() - pb_en.lambda_max()).abs() < 1e-10);
        let lambda = 0.15 * pb_en.lambda_max();
        let opts = SolveOptions { tol: 1e-12, ..Default::default() };
        let a = solve(&pb_stacked, lambda, None, &opts);
        let b = solve(&pb_en, lambda, None, &opts);
        for j in 0..p {
            assert!(
                (a.beta[j] - b.beta[j]).abs() < 1e-8,
                "j={j}: {} vs {}",
                a.beta[j],
                b.beta[j]
            );
        }
    }

    #[test]
    fn elastic_net_runs_on_csc() {
        // The point of dropping the row-stacked identity: EN now works on
        // sparse designs directly.
        let (x, y, groups) = data(6);
        let dense = elastic_net_problem(&x, &y, groups.clone(), 0.4, 1.5);
        let sparse = elastic_net_problem(&CscMatrix::from_dense(&x), &y, groups, 0.4, 1.5);
        let lambda = 0.2 * dense.lambda_max();
        let opts = SolveOptions { tol: 1e-10, ..Default::default() };
        let a = solve(&dense, lambda, None, &opts);
        let b = solve(&sparse, lambda, None, &opts);
        for j in 0..dense.p() {
            assert!((a.beta[j] - b.beta[j]).abs() < 1e-7, "j={j}");
        }
    }

    #[test]
    fn ridge_term_shrinks_solution() {
        let (x, y, groups) = data(2);
        let pb0 = elastic_net_problem(&x, &y, groups.clone(), 0.4, 0.0);
        let pb1 = elastic_net_problem(&x, &y, groups, 0.4, 5.0);
        let lambda = 0.1 * pb0.lambda_max();
        let opts = SolveOptions { tol: 1e-10, ..Default::default() };
        let a = solve(&pb0, lambda, None, &opts);
        let b = solve(&pb1, lambda, None, &opts);
        let na: f64 = a.beta.iter().map(|v| v * v).sum();
        let nb: f64 = b.beta.iter().map(|v| v * v).sum();
        assert!(nb < na, "ridge must shrink: {nb} vs {na}");
    }

    #[test]
    fn en_optimality_condition() {
        // Solve the EN problem and verify the *original* EN optimality in
        // terms of the fitted residual: for active coordinate j,
        // X_j^T(y - X beta) - lambda2 beta_j must match the subgradient
        // lambda1*(tau*sign + (1-tau) w_g beta_j/||beta_g||).
        let (x, y, groups) = data(3);
        let tau = 0.5;
        let lambda2 = 2.0;
        let pb = elastic_net_problem(&x, &y, groups.clone(), tau, lambda2);
        let lambda1 = 0.15 * pb.lambda_max();
        let res = solve(&pb, lambda1, None, &SolveOptions { tol: 1e-12, ..Default::default() });
        let fitted = x.matvec(&res.beta);
        let resid: Vec<f64> = y.iter().zip(&fitted).map(|(a, b)| a - b).collect();
        let corr = x.tmatvec(&resid);
        for (g, a, b) in groups.iter() {
            let bg = &res.beta[a..b];
            let ng: f64 = bg.iter().map(|v| v * v).sum::<f64>().sqrt();
            if ng == 0.0 {
                continue;
            }
            let w_g = pb.weights[g];
            for (k, j) in (a..b).enumerate() {
                if bg[k] != 0.0 {
                    let lhs = corr[j] - lambda2 * bg[k];
                    let rhs =
                        lambda1 * (tau * bg[k].signum() + (1.0 - tau) * w_g * bg[k] / ng);
                    assert!((lhs - rhs).abs() < 1e-6, "j={j}: {lhs} vs {rhs}");
                }
            }
        }
    }

    #[test]
    fn screening_works_on_augmented_problem() {
        let (x, y, groups) = data(4);
        let pb = elastic_net_problem(&x, &y, groups, 0.4, 1.0);
        let lambda = 0.5 * pb.lambda_max();
        let opts = SolveOptions { rule: RuleKind::GapSafe, tol: 1e-8, ..Default::default() };
        let res = solve(&pb, lambda, None, &opts);
        assert!(res.converged);
        assert!(res.active.n_active_features() < pb.p());
    }
}
