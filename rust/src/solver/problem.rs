//! The Sparse-Group Lasso problem instance (paper Eq. 5 with `Ω_{τ,w}`,
//! Eq. 10) together with the precomputed quantities every solver and
//! screening rule needs: column norms `‖X_j‖`, block spectral norms
//! `‖X_g‖₂`, block Lipschitz constants `L_g = ‖X_g‖₂²`, and `λ_max`
//! (Eq. 22).
//!
//! The instance is generic over the [`Design`] backend *and* the
//! [`Datafit`]: `SglProblem` (no parameters) is the dense least-squares
//! default, `SglProblem<CscMatrix>` the sparse instantiation, and
//! `SglProblem<D, Logistic>` a sparse-group logistic problem. Everything
//! downstream — solvers, screening rules, the path engine, the serving
//! stack — is generic over the same pair, so the whole stack runs
//! unchanged on any combination.
//!
//! Datafit-dependent constants are folded in **here, at construction**:
//! a ridge term `μ` augments `‖X_j‖ → √(‖X_j‖²+μ)`, `‖X_g‖₂ →
//! √(‖X_g‖₂²+μ)` and `L_g → L_g+μ` (the implicit `[X; √μI]` stacking),
//! and the logistic Hessian bound scales `L_g → ¼L_g`. The folds are
//! gated so the plain quadratic numbers stay bit-identical.

use super::datafit::{Datafit, Quadratic};
use super::groups::Groups;
use crate::linalg::{Design, Matrix};
use crate::norms::block::{omega_dual_argmax_rows, omega_dual_rows};
use crate::norms::sgl::{omega_dual, omega_dual_argmax};

/// An SGL problem `min_β f(β) + λ Ω_{τ,w}(β)` minus the choice of `λ`
/// (solvers take `λ` per call so one instance serves a whole path). The
/// smooth part `f` defaults to least squares `½‖y − Xβ‖²`.
#[derive(Clone, Debug)]
pub struct SglProblem<D: Design = Matrix, F: Datafit = Quadratic> {
    pub x: D,
    pub y: Vec<f64>,
    pub groups: Groups,
    /// Mixing parameter `τ ∈ [0, 1]`: 1 = Lasso, 0 = Group-Lasso (Rmk. 3).
    pub tau: f64,
    /// Group weights `w_g ≥ 0` (default `sqrt(n_g)`).
    pub weights: Vec<f64>,
    /// The smooth loss (see [`crate::solver::datafit`]).
    pub datafit: F,
    /// `‖X_j‖` for every feature (feature-level screening, Eq. 13),
    /// ridge-folded when the datafit carries an ℓ2 term.
    pub col_norms: Vec<f64>,
    /// `‖X_g‖₂` (spectral) for every group (group-level screening,
    /// Eq. 14), ridge-folded likewise.
    pub group_spectral_norms: Vec<f64>,
    /// Block majorization constants `L_g` (§6): `‖X_g‖₂²` scaled by the
    /// datafit's gradient-Lipschitz factor (¼ for logistic).
    pub lipschitz: Vec<f64>,
}

impl<D: Design> SglProblem<D, Quadratic> {
    /// Build a least-squares problem with the paper's default weights
    /// `w_g = sqrt(n_g)`.
    pub fn new(x: D, y: Vec<f64>, groups: Groups, tau: f64) -> Self {
        let w = groups.sqrt_size_weights();
        Self::with_weights(x, y, groups, tau, w)
    }

    /// Build a least-squares problem with explicit weights.
    pub fn with_weights(
        x: D,
        y: Vec<f64>,
        groups: Groups,
        tau: f64,
        weights: Vec<f64>,
    ) -> Self {
        Self::with_datafit(x, y, groups, tau, weights, Quadratic::default())
    }
}

impl<D: Design, F: Datafit> SglProblem<D, F> {
    /// Build with an explicit datafit (and explicit weights).
    pub fn with_datafit(
        x: D,
        y: Vec<f64>,
        groups: Groups,
        tau: f64,
        weights: Vec<f64>,
        datafit: F,
    ) -> Self {
        // Multi-response datafits carry `q = tasks()` response columns in
        // `y`, stored task-major (`y[t·n .. (t+1)·n]` is task t). Scalar
        // datafits have tasks() == 1, so this is the old `n == y.len()`.
        assert_eq!(
            x.n_rows() * datafit.tasks(),
            y.len(),
            "X/y row mismatch (y must hold n * tasks entries, task-major)"
        );
        assert_eq!(x.n_cols(), groups.p(), "X/groups column mismatch");
        assert_eq!(weights.len(), groups.n_groups(), "weights/groups mismatch");
        assert!((0.0..=1.0).contains(&tau), "tau must lie in [0, 1]");
        assert!(
            tau > 0.0 || weights.iter().all(|&w| w > 0.0),
            "tau = 0 with a zero weight is excluded (Omega not a norm)"
        );
        datafit.validate_y(&y);
        let mut col_norms = x.col_norms();
        let mut group_spectral_norms: Vec<f64> =
            groups.iter().map(|(_, a, b)| x.block_spectral_norm(a, b)).collect();
        let mu = datafit.ridge();
        if mu != 0.0 {
            // Implicit [X; √μI] row-stacking: ‖·‖² picks up +μ.
            for c in col_norms.iter_mut() {
                *c = (*c * *c + mu).sqrt();
            }
            for s in group_spectral_norms.iter_mut() {
                *s = (*s * *s + mu).sqrt();
            }
        }
        let mut lipschitz: Vec<f64> = group_spectral_norms.iter().map(|s| s * s).collect();
        let scale = datafit.grad_lip_scale();
        if scale != 1.0 {
            for l in lipschitz.iter_mut() {
                *l *= scale;
            }
        }
        SglProblem {
            x,
            y,
            groups,
            tau,
            weights,
            datafit,
            col_norms,
            group_spectral_norms,
            lipschitz,
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.x.n_cols()
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.groups.n_groups()
    }

    /// Number of response columns `q` (1 for every scalar datafit).
    #[inline]
    pub fn tasks(&self) -> usize {
        self.datafit.tasks()
    }

    /// `Xᵀ r₀` with `r₀` the datafit's residual at `β = 0`, feature-major
    /// (`p · q` entries; the plain `tmatvec` for scalar datafits). For the
    /// (multi-task) quadratic datafit this is `XᵀY` — the correlation
    /// panel the static/dynamic/DST3 screening centers are built from.
    pub fn xt_zero_residual(&self) -> Vec<f64> {
        let r0 = self.datafit.zero_residual(&self.y);
        let q = self.tasks();
        if q == 1 {
            return self.x.tmatvec(&r0);
        }
        let (n, p) = (self.n(), self.p());
        let mut out = vec![0.0; p * q];
        for t in 0..q {
            let xt = self.x.tmatvec(&r0[t * n..(t + 1) * n]);
            for (j, v) in xt.iter().enumerate() {
                out[j * q + t] = *v;
            }
        }
        out
    }

    /// Critical parameter `λ_max = Ω^D(Xᵀ r₀)` (Eq. 9 / 22) with `r₀` the
    /// datafit's residual at `β = 0` (`y` for least squares, `y − ½` for
    /// logistic): the smallest `λ` for which `β̂ = 0`. Multi-response
    /// datafits take the dual norm over the feature row norms of the
    /// `p × q` correlation matrix (arXiv 1506.03736).
    pub fn lambda_max(&self) -> f64 {
        let q = self.tasks();
        let xty = self.xt_zero_residual();
        if q == 1 {
            omega_dual(&xty, &self.groups, self.tau, &self.weights)
        } else {
            omega_dual_rows(&xty, q, &self.groups, self.tau, &self.weights)
        }
    }

    /// `λ_max` together with the argmax group `g★` (used by DST3, App. C).
    pub fn lambda_max_argmax(&self) -> (usize, f64) {
        let q = self.tasks();
        let xty = self.xt_zero_residual();
        if q == 1 {
            omega_dual_argmax(&xty, &self.groups, self.tau, &self.weights)
        } else {
            omega_dual_argmax_rows(&xty, q, &self.groups, self.tau, &self.weights)
        }
    }

    /// Re-parameterize the same design for a different `τ` (CV over τ grid
    /// reuses the precomputations, which do not depend on τ).
    pub fn with_tau(&self, tau: f64) -> Self {
        let mut p = self.clone();
        assert!((0.0..=1.0).contains(&tau));
        p.tau = tau;
        p
    }
}

/// The geometric λ grid of §7.1: `λ_t = λ_max · 10^{−δ t / (T−1)}`,
/// `t = 0..T-1`.
pub fn lambda_grid(lambda_max: f64, delta: f64, t_count: usize) -> Vec<f64> {
    assert!(t_count >= 1);
    if t_count == 1 {
        return vec![lambda_max];
    }
    (0..t_count)
        .map(|t| lambda_max * 10f64.powf(-delta * t as f64 / (t_count - 1) as f64))
        .collect()
}

impl SglProblem {
    /// See [`lambda_grid`] (kept as an associated function for existing
    /// call sites; the free function avoids pinning the backend parameter
    /// in generic code).
    pub fn lambda_grid(lambda_max: f64, delta: f64, t_count: usize) -> Vec<f64> {
        lambda_grid(lambda_max, delta, t_count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CscMatrix;
    use crate::norms::sgl::omega;
    use crate::solver::datafit::Logistic;
    use crate::util::rng::Pcg;

    fn random_problem(n: usize, sizes: &[usize], tau: f64, seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(sizes);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, tau)
    }

    #[test]
    fn shapes_and_precomputations() {
        let pb = random_problem(10, &[3, 2, 4], 0.5, 1);
        assert_eq!(pb.n(), 10);
        assert_eq!(pb.p(), 9);
        assert_eq!(pb.col_norms.len(), 9);
        assert_eq!(pb.lipschitz.len(), 3);
        // Lipschitz >= max column norm^2 within the group.
        for (g, a, b) in pb.groups.iter() {
            let max_col: f64 =
                pb.col_norms[a..b].iter().fold(0.0_f64, |m, &c| m.max(c * c));
            assert!(pb.lipschitz[g] >= max_col - 1e-9);
        }
    }

    #[test]
    fn csc_instantiation_matches_dense_precomputations() {
        let pb = random_problem(12, &[3, 3, 3], 0.4, 11);
        let sparse = SglProblem::new(
            CscMatrix::from_dense(&pb.x),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
        );
        for (a, b) in pb.col_norms.iter().zip(&sparse.col_norms) {
            assert!((a - b).abs() < 1e-10);
        }
        for (a, b) in pb.lipschitz.iter().zip(&sparse.lipschitz) {
            assert!((a - b).abs() < 1e-8 * a.max(1.0));
        }
        assert!((pb.lambda_max() - sparse.lambda_max()).abs() < 1e-9);
    }

    #[test]
    fn lambda_max_zeroes_the_solution() {
        // At lambda >= lambda_max the zero vector satisfies the optimality
        // condition Omega^D(X^T y) <= lambda (Remark 2): check the dual
        // norm identity directly.
        let pb = random_problem(12, &[2, 2, 2], 0.3, 2);
        let lmax = pb.lambda_max();
        assert!(lmax > 0.0);
        // beta = 0 is optimal iff lambda >= lmax: primal at 0 <= primal at
        // small perturbations along any feature direction.
        let p0 = 0.5 * pb.y.iter().map(|v| v * v).sum::<f64>();
        for j in 0..pb.p() {
            for s in [1e-5, -1e-5] {
                let mut beta = vec![0.0; pb.p()];
                beta[j] = s;
                let r: Vec<f64> =
                    pb.y.iter().enumerate().map(|(i, yi)| yi - pb.x.get(i, j) * s).collect();
                let pv = 0.5 * r.iter().map(|v| v * v).sum::<f64>()
                    + lmax * omega(&beta, &pb.groups, pb.tau, &pb.weights);
                assert!(pv >= p0 - 1e-9, "direction {j} improves at lambda_max");
            }
        }
    }

    #[test]
    fn lambda_grid_endpoints() {
        let grid = SglProblem::lambda_grid(10.0, 3.0, 100);
        assert_eq!(grid.len(), 100);
        assert!((grid[0] - 10.0).abs() < 1e-12);
        assert!((grid[99] - 10.0 * 1e-3).abs() < 1e-9);
        for w in grid.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert_eq!(SglProblem::lambda_grid(5.0, 3.0, 1), vec![5.0]);
    }

    #[test]
    fn argmax_group_attains_lambda_max() {
        let pb = random_problem(8, &[3, 3, 3], 0.4, 3);
        let (_g, val) = pb.lambda_max_argmax();
        assert!((val - pb.lambda_max()).abs() < 1e-12);
    }

    #[test]
    fn ridge_datafit_folds_norms_and_lipschitz() {
        let plain = random_problem(10, &[2, 3], 0.5, 21);
        let mu = 0.7;
        let en = SglProblem::with_datafit(
            plain.x.clone(),
            plain.y.clone(),
            plain.groups.clone(),
            plain.tau,
            plain.weights.clone(),
            Quadratic::with_ridge(mu),
        );
        for (c, ce) in plain.col_norms.iter().zip(&en.col_norms) {
            assert!((ce - (c * c + mu).sqrt()).abs() < 1e-12);
        }
        for (l, le) in plain.lipschitz.iter().zip(&en.lipschitz) {
            assert!((le - (l + mu)).abs() < 1e-9 * (l + mu));
        }
        // λ_max only sees the unstacked rows (the stacked ỹ block is 0).
        assert!((plain.lambda_max() - en.lambda_max()).abs() < 1e-12);
    }

    #[test]
    fn logistic_datafit_scales_lipschitz_by_quarter() {
        let plain = random_problem(10, &[2, 3], 0.5, 22);
        let y01: Vec<f64> = (0..10).map(|i| (i % 2) as f64).collect();
        let lg = SglProblem::with_datafit(
            plain.x.clone(),
            y01.clone(),
            plain.groups.clone(),
            plain.tau,
            plain.weights.clone(),
            Logistic,
        );
        for (l, ll) in plain.lipschitz.iter().zip(&lg.lipschitz) {
            assert_eq!(*ll, 0.25 * l);
        }
        assert_eq!(plain.col_norms, lg.col_norms);
        // λ_max = Ω^D(Xᵀ(y − ½)).
        let r0: Vec<f64> = y01.iter().map(|v| v - 0.5).collect();
        let expect = omega_dual(&lg.x.tmatvec(&r0), &lg.groups, lg.tau, &lg.weights);
        assert_eq!(lg.lambda_max(), expect);
    }

    #[test]
    fn multitask_q1_lambda_max_is_bitwise_scalar() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let pb = random_problem(10, &[2, 3, 2], 0.4, 31);
        let mt = SglProblem::with_datafit(
            pb.x.clone(),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
            pb.weights.clone(),
            MultiTaskQuadratic::new(1),
        );
        assert_eq!(mt.tasks(), 1);
        assert_eq!(pb.lambda_max().to_bits(), mt.lambda_max().to_bits());
        let (g1, v1) = pb.lambda_max_argmax();
        let (g2, v2) = mt.lambda_max_argmax();
        assert_eq!((g1, v1.to_bits()), (g2, v2.to_bits()));
        assert_eq!(pb.col_norms, mt.col_norms);
        assert_eq!(pb.lipschitz, mt.lipschitz);
    }

    #[test]
    fn multitask_lambda_max_takes_dual_norm_over_row_norms() {
        use crate::norms::block::row_norms;
        use crate::solver::datafit::MultiTaskQuadratic;
        let pb = random_problem(9, &[2, 2, 2], 0.5, 32);
        let q = 3;
        let n = pb.n();
        let mut rng = Pcg::seeded(77);
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        let mt = SglProblem::with_datafit(
            pb.x.clone(),
            y.clone(),
            pb.groups.clone(),
            pb.tau,
            pb.weights.clone(),
            MultiTaskQuadratic::new(q),
        );
        // Hand-rolled: per-task X^T y_t, gathered feature-major, row norms,
        // scalar dual norm.
        let mut xty = vec![0.0; mt.p() * q];
        for t in 0..q {
            let col = mt.x.tmatvec(&y[t * n..(t + 1) * n]);
            for (j, v) in col.iter().enumerate() {
                xty[j * q + t] = *v;
            }
        }
        let scores = row_norms(&xty, q);
        let expect = omega_dual(&scores, &mt.groups, mt.tau, &mt.weights);
        assert_eq!(mt.lambda_max().to_bits(), expect.to_bits());
    }

    #[test]
    #[should_panic(expected = "X/y row mismatch")]
    fn multitask_y_length_must_cover_all_tasks() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let groups = Groups::from_sizes(&[2]);
        let x = Matrix::zeros(3, 2);
        SglProblem::with_datafit(
            x,
            vec![0.0; 3], // needs 3 * 2 = 6 entries for q = 2
            groups.clone(),
            0.5,
            groups.sqrt_size_weights(),
            MultiTaskQuadratic::new(2),
        );
    }

    #[test]
    #[should_panic(expected = "logistic labels")]
    fn logistic_rejects_real_valued_targets() {
        let groups = Groups::from_sizes(&[2]);
        let x = Matrix::zeros(3, 2);
        SglProblem::with_datafit(
            x,
            vec![0.0, 2.5, 1.0],
            groups.clone(),
            0.5,
            groups.sqrt_size_weights(),
            Logistic,
        );
    }

    #[test]
    #[should_panic]
    fn tau_zero_with_zero_weight_rejected() {
        let groups = Groups::from_sizes(&[2]);
        let x = Matrix::zeros(3, 2);
        SglProblem::with_weights(x, vec![0.0; 3], groups, 0.0, vec![0.0]);
    }
}
