//! Full proximal-gradient (ISTA) solver on the shared active-set core.
//!
//! This is the *parallel-friendly* variant of Algorithm 2: instead of a
//! cyclic sweep with incremental residual updates, each iteration takes a
//! global gradient step `u = β + Xᵀρ / L` (with `L = ‖X‖₂²`) followed by
//! the separable SGL prox over all groups simultaneously. It converges more
//! slowly per epoch than ISTA-BC but is exactly the computation shape of the
//! AOT-compiled XLA artifact (`python/compile/model.py:ista_epoch`): fixed
//! tensor shapes, masking instead of index lists. The native version here is
//! the oracle the XLA engine is integration-tested against.
//!
//! Since PR 2 the native ISTA drives the same active-set core as CD
//! ([`crate::solver::active_set`]): the gradient sweep and the residual
//! recompute stream the *compacted* surviving columns (`O(n·p_active)`
//! dense, `O(nnz_active)` CSC, vs. the former full `O(n·p)` per epoch),
//! and the terminal dual point is handed to sequential rules through
//! `on_solve_complete` — closing the solver-symmetry gap left by PR 1.
//! Gap checks still evaluate the full `Xᵀρ`: the dual scaling `Ω^D(Xᵀρ)`
//! of Eq. 15 needs every feature, screened or not.

use super::active_set::ScreenState;
use super::datafit::Datafit;
use super::duality::DualSnapshot;
use super::problem::SglProblem;
use super::sweep;
use crate::linalg::spectral::power_iteration;
use crate::linalg::Design;
use crate::screening::{make_rule, ScreeningRule};
use crate::solver::cd::{SolveOptions, SolveResult};
use crate::util::timer::Stopwatch;
use crate::util::trace;

/// Global Lipschitz constant `‖X‖₂²` (top eigenvalue of `XᵀX`) of the
/// design alone; see [`global_step_lipschitz`] for the full-gradient step
/// constant of a given datafit.
pub fn global_lipschitz<D: Design, F: Datafit>(pb: &SglProblem<D, F>) -> f64 {
    let x = &pb.x;
    power_iteration(
        pb.p(),
        |v| {
            let u = x.matvec(v);
            x.tmatvec(&u)
        },
        1e-12,
        2000,
        0xC0FFEE,
    )
}

/// Lipschitz constant of the full gradient `∇_β f(Xβ)`: `‖X‖₂²` scaled by
/// the datafit's curvature bound (¼ for logistic) plus its ridge term.
/// Plain least squares takes neither branch, so the value — and therefore
/// every historical iterate — is bit-identical to [`global_lipschitz`].
pub fn global_step_lipschitz<D: Design, F: Datafit>(pb: &SglProblem<D, F>) -> f64 {
    let mut l = global_lipschitz(pb);
    let gs = pb.datafit.grad_lip_scale();
    if gs != 1.0 {
        l *= gs;
    }
    let mu = pb.datafit.ridge();
    if mu != 0.0 {
        l += mu;
    }
    l
}

/// ISTA solve at a single `λ` with masked screening. Mirrors
/// `solver::cd::solve`'s interface and result type.
pub fn solve_ista<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let mut rule = make_rule(opts.rule, pb);
    solve_ista_with_rule(pb, lambda, beta0, opts, rule.as_mut())
}

/// ISTA with a caller-provided rule instance (path solves construct the
/// rule once and carry it across the grid, exactly like `cd`).
pub fn solve_ista_with_rule<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
    rule: &mut dyn ScreeningRule<D, F>,
) -> SolveResult {
    assert!(lambda > 0.0, "lambda must be positive");
    let sw = Stopwatch::start();
    let p = pb.p();
    let _solve_span = trace::span_with("solve", || {
        vec![("solver", "ista".into()), ("lambda", lambda.into()), ("p", p.into())]
    });
    let q = pb.datafit.tasks();
    let l_global = global_step_lipschitz(pb).max(1e-300);
    let mut state = ScreenState::new(pb, opts);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p * q]);
    assert_eq!(beta.len(), p * q, "warm start must be feature-major p * tasks");
    let mut fit = pb.datafit.init_state(&pb.x, &pb.y, &beta);
    let mut epochs_done = 0usize;
    let mut xt_rho = vec![0.0; p * q];
    // Per-worker prox blocks, allocated once for the whole solve (d × q
    // panels in the multi-task case).
    let max_group = (0..pb.n_groups()).map(|g| pb.groups.size(g)).max().unwrap_or(0);
    let mut prox_scratch = sweep::ProxScratch::new(max_group * q, state.sweep.threads());

    for epoch in 0..opts.max_epochs {
        if epoch % opts.fce == 0 {
            // Full correlation vector: the dual scaling needs every
            // feature, so gap checks cost one full Xᵀρ by design.
            sweep::xt_full(&state.sweep, pb, fit.residual(), &mut xt_rho);
            let snap = DualSnapshot::compute_state_with_xt_rho_ctx(
                pb,
                &beta,
                fit.as_ref(),
                &xt_rho,
                lambda,
                &state.sweep,
            );
            let out =
                state.gap_check(pb, lambda, epoch, rule, &mut beta, &mut fit, snap, &sw);
            if out.converged {
                epochs_done = epoch;
                break;
            }
        }

        // u = beta + X^T rho / L on the compacted active columns, then the
        // separable prox group by group. Both sweeps route through the
        // sweep context: every group update reads the same Xᵀρ, so the
        // parallel branches are bit-identical to the serial loops.
        sweep::xt_active(&state.sweep, &state.cols, pb, fit.residual(), &mut xt_rho);
        let mu = pb.datafit.ridge();
        if mu != 0.0 {
            // Ridge term of the gradient (implicit elastic net): the
            // augmented rows contribute −μβ_j to each correlation. No
            // ridge-carrying datafit is multi-task today.
            debug_assert_eq!(q, 1, "ridge gradient path is scalar-only");
            for k in 0..state.cols.n_active() {
                let j = state.cols.feature(k);
                xt_rho[j] -= mu * beta[j];
            }
        }
        let changed = sweep::ista_sweep(
            &state.sweep,
            &state.cols,
            pb,
            lambda,
            l_global,
            &mut beta,
            &xt_rho,
            &mut prox_scratch,
        );
        // Full state recompute over the active columns (matches the
        // artifact's dataflow; screened coordinates are zero).
        if changed {
            sweep::refresh_state(&state.sweep, &state.cols, pb, &beta, &mut fit);
        }
        epochs_done = epoch + 1;
    }

    state.finalize(pb, lambda, rule, &beta, &fit);
    state.into_result(beta, epochs_done, sw.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::screening::RuleKind;
    use crate::solver::cd;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn random_problem(n: usize, sizes: &[usize], tau: f64, seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(sizes);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let mut beta_true = vec![0.0; p];
        beta_true[0] = 1.5;
        beta_true[p - 1] = -2.0;
        let xb = x.matvec(&beta_true);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.01 * rng.normal()).collect();
        SglProblem::new(x, y, groups, tau)
    }

    #[test]
    fn global_lipschitz_dominates_blocks() {
        let pb = random_problem(20, &[3, 3, 3], 0.5, 1);
        let l = global_lipschitz(&pb);
        for &lg in &pb.lipschitz {
            assert!(l >= lg - 1e-8, "L={l} < Lg={lg}");
        }
    }

    #[test]
    fn ista_and_cd_agree() {
        let pb = random_problem(25, &[3, 3, 3, 3], 0.35, 2);
        let lambda = 0.2 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, max_epochs: 200_000, ..Default::default() };
        let a = cd::solve(&pb, lambda, None, &opts);
        let b = solve_ista(&pb, lambda, None, &opts);
        assert!(a.converged && b.converged, "cd={} ista={}", a.gap, b.gap);
        for j in 0..pb.p() {
            assert!(
                (a.beta[j] - b.beta[j]).abs() < 1e-4,
                "j={j}: {} vs {}",
                a.beta[j],
                b.beta[j]
            );
        }
    }

    #[test]
    fn ista_converges_with_each_rule() {
        let pb = random_problem(20, &[4, 4, 4], 0.4, 3);
        let lambda = 0.3 * pb.lambda_max();
        for rule in RuleKind::all() {
            let opts =
                SolveOptions { rule, tol: 1e-8, max_epochs: 200_000, ..Default::default() };
            let res = solve_ista(&pb, lambda, None, &opts);
            assert!(res.converged, "{rule:?}: gap={}", res.gap);
        }
    }

    #[test]
    fn multitask_ista_and_cd_agree() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let q = 2;
        let groups = Groups::from_sizes(&[3, 3, 3]);
        let p = groups.p();
        let n = 20;
        let mut rng = Pcg::seeded(9);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        let w = groups.sqrt_size_weights();
        let pb = SglProblem::with_datafit(x, y, groups, 0.35, w, MultiTaskQuadratic::new(q));
        let lambda = 0.2 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, max_epochs: 200_000, ..Default::default() };
        let a = cd::solve(&pb, lambda, None, &opts);
        let b = solve_ista(&pb, lambda, None, &opts);
        assert!(a.converged && b.converged, "cd={} ista={}", a.gap, b.gap);
        for i in 0..p * q {
            assert!(
                (a.beta[i] - b.beta[i]).abs() < 1e-4,
                "i={i}: {} vs {}",
                a.beta[i],
                b.beta[i]
            );
        }
    }

    #[test]
    fn zero_solution_above_lambda_max() {
        let pb = random_problem(15, &[2, 2, 2], 0.5, 4);
        let res = solve_ista(&pb, 1.5 * pb.lambda_max(), None, &SolveOptions::default());
        assert!(res.beta.iter().all(|&b| b == 0.0));
        assert!(res.converged);
    }
}
