//! Full proximal-gradient (ISTA) solver with masked active sets.
//!
//! This is the *parallel-friendly* variant of Algorithm 2: instead of a
//! cyclic sweep with incremental residual updates, each iteration takes a
//! global gradient step `u = β + Xᵀρ / L` (with `L = ‖X‖₂²`) followed by
//! the separable SGL prox over all groups simultaneously. It converges more
//! slowly per epoch than ISTA-BC but is exactly the computation shape of the
//! AOT-compiled XLA artifact (`python/compile/model.py:ista_epoch`): fixed
//! tensor shapes, masking instead of index lists. The native version here is
//! the oracle the XLA engine is integration-tested against.

use super::duality::DualSnapshot;
use super::problem::SglProblem;
use crate::linalg::spectral::power_iteration;
use crate::norms::prox::sgl_prox_inplace;
use crate::screening::{apply_sphere, make_rule, ActiveSet};
use crate::solver::cd::{CheckEvent, SolveOptions, SolveResult};
use crate::util::timer::Stopwatch;

/// Global Lipschitz constant `‖X‖₂²` (top eigenvalue of `XᵀX`).
pub fn global_lipschitz(pb: &SglProblem) -> f64 {
    let x = &pb.x;
    power_iteration(
        pb.p(),
        |v| {
            let u = x.matvec(v);
            x.tmatvec(&u)
        },
        1e-12,
        2000,
        0xC0FFEE,
    )
}

/// ISTA solve at a single `λ` with masked screening. Mirrors
/// `solver::cd::solve`'s interface and result type.
pub fn solve_ista(
    pb: &SglProblem,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let sw = Stopwatch::start();
    let p = pb.p();
    // Relative-to-||y||^2 stopping threshold (see SolveOptions::tol).
    let tol_abs = opts.tol * crate::linalg::ops::l2_norm_sq(&pb.y).max(f64::MIN_POSITIVE);
    let l_global = global_lipschitz(pb).max(1e-300);
    let mut rule = make_rule(opts.rule, pb);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut rho = pb.y.clone();
    if beta.iter().any(|&b| b != 0.0) {
        let xb = pb.x.matvec(&beta);
        for (r, v) in rho.iter_mut().zip(&xb) {
            *r -= v;
        }
    }
    let mut active = ActiveSet::full(&pb.groups);
    let mut history = Vec::new();
    let mut gap = f64::INFINITY;
    let mut gap_evals = 0usize;
    let mut converged = false;
    let mut epochs_done = 0usize;
    let mut xt_rho = vec![0.0; p];
    // Scratch block reused across groups/epochs (was a per-group alloc).
    let max_group = (0..pb.n_groups()).map(|g| pb.groups.size(g)).max().unwrap_or(0);
    let mut block = vec![0.0; max_group];

    for epoch in 0..opts.max_epochs {
        if epoch % opts.fce == 0 {
            pb.x.tmatvec_into(&rho, &mut xt_rho);
            let snap = DualSnapshot::compute_with_xt_rho(pb, &beta, &rho, &xt_rho, lambda);
            gap = snap.gap;
            gap_evals += 1;
            if let Some(sphere) = rule.sphere(pb, lambda, &snap) {
                let out = apply_sphere(pb, &sphere, &mut active, &mut beta, &mut rho);
                if out.beta_changed && gap <= tol_abs {
                    let snap2 = DualSnapshot::compute(pb, &beta, &rho, lambda);
                    gap = snap2.gap;
                    gap_evals += 1;
                }
            }
            if opts.record_history {
                history.push(CheckEvent {
                    epoch,
                    gap,
                    radius: snap.radius,
                    active_features: active.n_active_features(),
                    active_groups: active.n_active_groups(),
                    elapsed_s: sw.elapsed_s(),
                });
            }
            if gap <= tol_abs {
                converged = true;
                epochs_done = epoch;
                break;
            }
        }

        // u = beta + X^T rho / L on active features, then the separable prox.
        pb.x.tmatvec_into(&rho, &mut xt_rho);
        let mut changed = false;
        for (g, a, b) in pb.groups.iter() {
            if !active.group[g] {
                continue;
            }
            // Masked gradient step into the reusable scratch block.
            let d = b - a;
            for (k, j) in (a..b).enumerate() {
                block[k] =
                    if active.feature[j] { beta[j] + xt_rho[j] / l_global } else { 0.0 };
            }
            sgl_prox_inplace(
                &mut block[..d],
                pb.tau * lambda / l_global,
                (1.0 - pb.tau) * pb.weights[g] * lambda / l_global,
            );
            for (k, j) in (a..b).enumerate() {
                let new = if active.feature[j] { block[k] } else { 0.0 };
                if new != beta[j] {
                    beta[j] = new;
                    changed = true;
                }
            }
        }
        // Full residual recompute (matches the artifact's dataflow).
        if changed {
            let xb = pb.x.matvec(&beta);
            for (r, (y, v)) in rho.iter_mut().zip(pb.y.iter().zip(&xb)) {
                *r = y - v;
            }
        }
        epochs_done = epoch + 1;
    }

    if !converged {
        let snap = DualSnapshot::compute(pb, &beta, &rho, lambda);
        gap = snap.gap;
        gap_evals += 1;
        converged = gap <= tol_abs;
    }

    SolveResult {
        beta,
        gap,
        epochs: epochs_done,
        converged,
        elapsed_s: sw.elapsed_s(),
        active,
        history,
        gap_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::screening::RuleKind;
    use crate::solver::cd;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn random_problem(n: usize, sizes: &[usize], tau: f64, seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(sizes);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let mut beta_true = vec![0.0; p];
        beta_true[0] = 1.5;
        beta_true[p - 1] = -2.0;
        let xb = x.matvec(&beta_true);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.01 * rng.normal()).collect();
        SglProblem::new(x, y, groups, tau)
    }

    #[test]
    fn global_lipschitz_dominates_blocks() {
        let pb = random_problem(20, &[3, 3, 3], 0.5, 1);
        let l = global_lipschitz(&pb);
        for &lg in &pb.lipschitz {
            assert!(l >= lg - 1e-8, "L={l} < Lg={lg}");
        }
    }

    #[test]
    fn ista_and_cd_agree() {
        let pb = random_problem(25, &[3, 3, 3, 3], 0.35, 2);
        let lambda = 0.2 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, max_epochs: 200_000, ..Default::default() };
        let a = cd::solve(&pb, lambda, None, &opts);
        let b = solve_ista(&pb, lambda, None, &opts);
        assert!(a.converged && b.converged, "cd={} ista={}", a.gap, b.gap);
        for j in 0..pb.p() {
            assert!(
                (a.beta[j] - b.beta[j]).abs() < 1e-4,
                "j={j}: {} vs {}",
                a.beta[j],
                b.beta[j]
            );
        }
    }

    #[test]
    fn ista_converges_with_each_rule() {
        let pb = random_problem(20, &[4, 4, 4], 0.4, 3);
        let lambda = 0.3 * pb.lambda_max();
        for rule in RuleKind::all() {
            let opts =
                SolveOptions { rule, tol: 1e-8, max_epochs: 200_000, ..Default::default() };
            let res = solve_ista(&pb, lambda, None, &opts);
            assert!(res.converged, "{rule:?}: gap={}", res.gap);
        }
    }

    #[test]
    fn zero_solution_above_lambda_max() {
        let pb = random_problem(15, &[2, 2, 2], 0.5, 4);
        let res = solve_ista(&pb, 1.5 * pb.lambda_max(), None, &SolveOptions::default());
        assert!(res.beta.iter().all(|&b| b == 0.0));
        assert!(res.converged);
    }
}
