//! ISTA-BC: block coordinate descent with dynamic GAP safe screening —
//! paper Algorithm 2.
//!
//! Each epoch sweeps the active groups cyclically. For group `g` the update
//! is the Majorization-Minimization step of §6:
//!
//! ```text
//!   β_g ← S^gp_{(1−τ) w_g α_g} ( S_{τ α_g} ( β_g + X_gᵀρ / L_g ) ),
//!   α_g = λ / L_g,   L_g = ‖X_g‖₂²,
//! ```
//!
//! with the residual `ρ = y − Xβ` maintained incrementally (`O(n)` per
//! touched coordinate on the dense backend, `O(nnz_j)` on CSC). Every
//! `f_ce` epochs (paper default: 10) the duality gap is evaluated: it
//! provides both the stopping test and — through the configured
//! [`ScreeningRule`] — a safe sphere used to eliminate variables.
//!
//! The solver is generic over the [`Design`] backend and drives the shared
//! active-set core ([`crate::solver::active_set`]): column compaction
//! after screening events, the gap-check plumbing, and the
//! `on_solve_complete` terminal-dual handoff all live there, shared with
//! ISTA and FISTA.

use super::active_set::ScreenState;
use super::datafit::Datafit;
use super::duality::DualSnapshot;
use super::problem::SglProblem;
use super::sweep::{self, SweepMode};
use crate::linalg::Design;
use crate::norms::block::sgl_prox_rows_inplace;
use crate::norms::prox::sgl_prox_inplace;
use crate::screening::{make_rule, ActiveSet, RuleKind, ScreeningRule};
use crate::util::timer::Stopwatch;
use crate::util::trace;

/// Solver options (paper defaults).
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Target duality gap, **relative to `‖y‖²`** (the paper sweeps
    /// 1e-2 .. 1e-8). The solver stops when `P(β) − D(θ) ≤ tol·‖y‖²`,
    /// matching the authors' implementation (and scikit-learn's
    /// convention) — an absolute gap would not be scale-free across
    /// datasets.
    pub tol: f64,
    /// Maximum number of epochs (full passes over active variables).
    pub max_epochs: usize,
    /// Gap-evaluation / screening frequency in epochs (`f_ce`, paper: 10).
    pub fce: usize,
    /// Screening rule to apply at every gap evaluation.
    pub rule: RuleKind,
    /// Record per-check active-set statistics (Fig. 2a/2b need them;
    /// benches turn this off).
    pub record_history: bool,
    /// Epoch execution mode ([`crate::solver::sweep`]): the default
    /// serial cyclic sweep, or work-stealing parallel sweeps over the
    /// active-set group ranges (bit-identical for ISTA/FISTA,
    /// bulk-synchronous rounds for CD).
    pub sweep: SweepMode,
    /// Worker threads for `sweep = "parallel"` (0 = auto: the
    /// `SGL_THREADS` / available-parallelism default). Ignored in serial
    /// mode.
    pub sweep_threads: usize,
    /// Engage floors and round sizing for the parallel sweep kernels
    /// ([`crate::solver::sweep::SweepTuning`]); the defaults are the
    /// constants the kernels shipped with. No effect in serial mode.
    pub tuning: sweep::SweepTuning,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            tol: 1e-8,
            max_epochs: 20_000,
            fce: 10,
            rule: RuleKind::GapSafe,
            record_history: true,
            sweep: SweepMode::Serial,
            sweep_threads: 0,
            tuning: sweep::SweepTuning::default(),
        }
    }
}

/// One gap-evaluation checkpoint.
#[derive(Clone, Debug)]
pub struct CheckEvent {
    pub epoch: usize,
    pub gap: f64,
    pub radius: f64,
    pub active_features: usize,
    pub active_groups: usize,
    /// Seconds since solve start.
    pub elapsed_s: f64,
}

/// Result of a single-λ solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub beta: Vec<f64>,
    pub gap: f64,
    pub epochs: usize,
    pub converged: bool,
    pub elapsed_s: f64,
    pub active: ActiveSet,
    pub history: Vec<CheckEvent>,
    /// Total number of gap evaluations (each costs one `Xᵀρ`).
    pub gap_evals: usize,
}

/// Solve one SGL problem at a single `λ` with warm start `beta0`.
pub fn solve<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let mut rule = make_rule(opts.rule, pb);
    solve_with_rule(pb, lambda, beta0, opts, rule.as_mut())
}

/// Solve with a caller-provided rule instance (path solves construct the
/// rule once and reuse its precomputations across the grid).
pub fn solve_with_rule<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambda: f64,
    beta0: Option<&[f64]>,
    opts: &SolveOptions,
    rule: &mut dyn ScreeningRule<D, F>,
) -> SolveResult {
    assert!(lambda > 0.0, "lambda must be positive");
    let p = pb.p();
    let q = pb.datafit.tasks();
    let sw = Stopwatch::start();
    let _solve_span = trace::span_with("solve", || {
        vec![("solver", "cd".into()), ("lambda", lambda.into()), ("p", p.into())]
    });
    let mut state = ScreenState::new(pb, opts);

    let mut beta = match beta0 {
        Some(b) => {
            assert_eq!(b.len(), p * q, "warm start must be feature-major p * tasks");
            b.to_vec()
        }
        None => vec![0.0; p * q],
    };
    // The maintained datafit state: ρ = y − Xβ for quadratic, Xβ (plus
    // the derived residual y − σ(Xβ)) for logistic.
    let mut fit = pb.datafit.init_state(&pb.x, &pb.y, &beta);

    let mut epochs_done = 0usize;
    // Scratch block buffer sized to the largest group (a d × q panel per
    // group in the multi-task case).
    let max_group = (0..pb.n_groups()).map(|g| pb.groups.size(g)).max().unwrap_or(0);
    let mut block = vec![0.0; max_group * q];
    // Bulk-synchronous round buffers, only when `sweep = "parallel"`.
    let mut par_scratch = state
        .sweep
        .is_parallel()
        .then(|| sweep::CdParScratch::new(p, state.sweep.threads()));

    for epoch in 0..opts.max_epochs {
        // ---- gap evaluation + screening every fce epochs (incl. epoch 0)
        if epoch % opts.fce == 0 {
            // Refresh the residual from scratch every 10th check: the
            // incremental updates accumulate drift over thousands of
            // epochs, which would make the gap (and hence the safe radius)
            // dishonest. Every check would cost one extra matvec (§Perf);
            // the radius floor in DualSnapshot covers the short horizon.
            if state.gap_evals % 10 == 0 {
                sweep::refresh_state(&state.sweep, &state.cols, pb, &beta, &mut fit);
            }
            let snap =
                DualSnapshot::compute_state_ctx(pb, &beta, fit.as_ref(), lambda, &state.sweep);
            let out =
                state.gap_check(pb, lambda, epoch, rule, &mut beta, &mut fit, snap, &sw);
            if out.converged {
                epochs_done = epoch;
                break;
            }
        }

        // ---- one pass over the (compacted) active groups: parallel
        // bulk-synchronous rounds when the mode is on, the datafit admits
        // the speculative accept test, and the active set is large enough
        // to feed the crew, else the serial cyclic sweep.
        if pb.datafit.supports_parallel_cd()
            && state.sweep.engage(state.cols.groups().len(), state.sweep.tuning.cd_floor)
        {
            sweep::cd_epoch_parallel(
                &state.sweep,
                par_scratch.as_mut().expect("engage implies parallel mode"),
                pb,
                &state.cols,
                lambda,
                &mut beta,
                &mut fit.main,
            );
        } else if q > 1 {
            // Multi-task serial sweep: the same MM block step on d × q
            // panels — the prox is a row soft-threshold followed by a
            // Frobenius group shrink — with the task-major residual
            // maintained one task slice at a time.
            let n = pb.n();
            let sign = pb.datafit.delta_sign();
            for &(g, s, e) in state.cols.groups() {
                let lg = pb.lipschitz[g];
                if lg == 0.0 {
                    continue;
                }
                let alpha_g = lambda / lg;
                let d = e - s;
                {
                    let resid: &[f64] = &fit.main;
                    for (k, idx) in (s..e).enumerate() {
                        let j = state.cols.feature(idx);
                        for t in 0..q {
                            let corr =
                                state.cols.col_dot(pb, idx, &resid[t * n..(t + 1) * n]);
                            block[k * q + t] = beta[j * q + t] + corr / lg;
                        }
                    }
                }
                sgl_prox_rows_inplace(
                    &mut block[..d * q],
                    q,
                    pb.tau * alpha_g,
                    (1.0 - pb.tau) * pb.weights[g] * alpha_g,
                );
                for (k, idx) in (s..e).enumerate() {
                    let j = state.cols.feature(idx);
                    for t in 0..q {
                        let delta = block[k * q + t] - beta[j * q + t];
                        if delta != 0.0 {
                            beta[j * q + t] = block[k * q + t];
                            state.cols.col_axpy(
                                pb,
                                idx,
                                sign * delta,
                                &mut fit.main[t * n..(t + 1) * n],
                            );
                        }
                    }
                }
            }
        } else {
            let sign = pb.datafit.delta_sign();
            for &(g, s, e) in state.cols.groups() {
                let lg = pb.lipschitz[g];
                if lg == 0.0 {
                    continue;
                }
                let alpha_g = lambda / lg;
                let d = e - s;
                // u = beta_g + grad_g / L_g (restricted to active
                // features), streaming the packed columns against the
                // generalized residual. `L_g` already carries the
                // datafit's gradient-Lipschitz scale (problem
                // construction), so the MM majorization holds per block.
                {
                    let resid = fit.residual();
                    for (k, idx) in (s..e).enumerate() {
                        let j = state.cols.feature(idx);
                        let corr = state.cols.col_dot(pb, idx, resid);
                        block[k] = beta[j] + pb.datafit.grad_correction(corr, beta[j]) / lg;
                    }
                }
                sgl_prox_inplace(
                    &mut block[..d],
                    pb.tau * alpha_g,
                    (1.0 - pb.tau) * pb.weights[g] * alpha_g,
                );
                // Apply deltas, maintain the state vector, and re-sync the
                // derived residual once per touched group (no-op for
                // residual-state datafits).
                let mut touched = false;
                for (k, idx) in (s..e).enumerate() {
                    let j = state.cols.feature(idx);
                    let delta = block[k] - beta[j];
                    if delta != 0.0 {
                        beta[j] = block[k];
                        state.cols.col_axpy(pb, idx, sign * delta, &mut fit.main);
                        touched = true;
                    }
                }
                if touched {
                    pb.datafit.sync_residual(&pb.y, &mut fit);
                }
            }
        }
        epochs_done = epoch + 1;
    }

    // Terminal gap (if the budget ran out) + the sequential-rule handoff.
    state.finalize(pb, lambda, rule, &beta, &fit);
    state.into_result(beta, epochs_done, sw.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::norms::sgl::omega;
    use crate::solver::duality::duality_gap;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    pub fn random_problem(n: usize, sizes: &[usize], tau: f64, seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(sizes);
        let p = groups.p();
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        // Planted sparse model.
        let mut beta_true = vec![0.0; p];
        beta_true[0] = 2.0;
        beta_true[1] = -1.5;
        if p > 4 {
            beta_true[4] = 1.0;
        }
        let xb = x.matvec(&beta_true);
        let y: Vec<f64> = xb.iter().map(|v| v + 0.01 * rng.normal()).collect();
        SglProblem::new(x, y, groups, tau)
    }

    #[test]
    fn converges_to_tolerance() {
        let pb = random_problem(30, &[3, 3, 3, 3], 0.3, 1);
        let lambda = 0.1 * pb.lambda_max();
        let res = solve(&pb, lambda, None, &SolveOptions::default());
        assert!(res.converged, "gap={}", res.gap);
        let tol_abs = 1e-8 * pb.y.iter().map(|v| v * v).sum::<f64>();
        assert!(res.gap <= tol_abs);
        // Verify gap independently.
        let g = duality_gap(&pb, &res.beta, lambda);
        assert!(g <= 1.01 * tol_abs, "true gap {g}");
    }

    #[test]
    fn all_rules_reach_same_objective() {
        let pb = random_problem(25, &[4, 4, 4], 0.4, 2);
        let lambda = 0.15 * pb.lambda_max();
        let mut objectives = Vec::new();
        for rule in RuleKind::all() {
            let opts = SolveOptions { rule, tol: 1e-10, ..Default::default() };
            let res = solve(&pb, lambda, None, &opts);
            assert!(res.converged, "{:?} gap={}", rule, res.gap);
            let xb = pb.x.matvec(&res.beta);
            let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
            let obj = 0.5 * rho.iter().map(|r| r * r).sum::<f64>()
                + lambda * omega(&res.beta, &pb.groups, pb.tau, &pb.weights);
            objectives.push(obj);
        }
        for o in &objectives[1..] {
            assert!((o - objectives[0]).abs() < 1e-7, "{objectives:?}");
        }
    }

    #[test]
    fn screening_is_safe_against_reference() {
        // Any variable screened along the way must be zero in a
        // high-precision no-screening reference solution.
        let pb = random_problem(20, &[2, 2, 2, 2, 2], 0.5, 3);
        let lambda = 0.3 * pb.lambda_max();
        let reference = solve(
            &pb,
            lambda,
            None,
            &SolveOptions { rule: RuleKind::None, tol: 1e-12, ..Default::default() },
        );
        for rule in [
            RuleKind::Static,
            RuleKind::Dynamic,
            RuleKind::Dst3,
            RuleKind::GapSafe,
            RuleKind::GapSafeSeq,
        ] {
            let res = solve(
                &pb,
                lambda,
                None,
                &SolveOptions { rule, tol: 1e-10, ..Default::default() },
            );
            for j in 0..pb.p() {
                if !res.active.feature[j] {
                    assert!(
                        reference.beta[j].abs() < 1e-6,
                        "{rule:?} screened feature {j} with ref beta {}",
                        reference.beta[j]
                    );
                }
            }
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let pb = random_problem(30, &[3, 3, 3, 3], 0.3, 4);
        let lmax = pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, ..Default::default() };
        let first = solve(&pb, 0.5 * lmax, None, &opts);
        let cold = solve(&pb, 0.4 * lmax, None, &opts);
        let warm = solve(&pb, 0.4 * lmax, Some(&first.beta), &opts);
        assert!(warm.epochs <= cold.epochs, "warm {} vs cold {}", warm.epochs, cold.epochs);
        assert!(warm.converged && cold.converged);
    }

    #[test]
    fn lambda_above_max_yields_zero() {
        let pb = random_problem(15, &[3, 3], 0.6, 5);
        let res = solve(&pb, 1.1 * pb.lambda_max(), None, &SolveOptions::default());
        assert!(res.converged);
        assert!(res.beta.iter().all(|&b| b == 0.0));
        assert_eq!(res.epochs, 0);
    }

    #[test]
    fn gap_safe_screens_most() {
        // At moderately large lambda, GAP safe should end with no more
        // active features than the static rule.
        let pb = random_problem(40, &[5; 8], 0.2, 6);
        let lambda = 0.5 * pb.lambda_max();
        let opts = |rule| SolveOptions { rule, tol: 1e-8, ..Default::default() };
        let gap = solve(&pb, lambda, None, &opts(RuleKind::GapSafe));
        let stat = solve(&pb, lambda, None, &opts(RuleKind::Static));
        assert!(
            gap.active.n_active_features() <= stat.active.n_active_features(),
            "gap {} vs static {}",
            gap.active.n_active_features(),
            stat.active.n_active_features()
        );
    }

    #[test]
    fn history_is_recorded_and_monotone_active() {
        let pb = random_problem(25, &[4, 4, 4], 0.3, 7);
        let res = solve(&pb, 0.2 * pb.lambda_max(), None, &SolveOptions::default());
        assert!(!res.history.is_empty());
        for w in res.history.windows(2) {
            assert!(w[1].active_features <= w[0].active_features);
            assert!(w[1].epoch > w[0].epoch);
        }
    }

    #[test]
    fn multitask_q1_solve_is_bitwise_scalar() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let pb = random_problem(20, &[3, 3, 2], 0.4, 11);
        let mt = SglProblem::with_datafit(
            pb.x.clone(),
            pb.y.clone(),
            pb.groups.clone(),
            pb.tau,
            pb.weights.clone(),
            MultiTaskQuadratic::new(1),
        );
        let lambda = 0.2 * pb.lambda_max();
        assert_eq!(lambda.to_bits(), (0.2 * mt.lambda_max()).to_bits());
        let opts = SolveOptions::default();
        let a = solve(&pb, lambda, None, &opts);
        let b = solve(&mt, lambda, None, &opts);
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        for (x, y) in a.beta.iter().zip(&b.beta) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.active.feature, b.active.feature);
    }

    #[test]
    fn multitask_converges_and_respects_screening() {
        use crate::solver::datafit::MultiTaskQuadratic;
        let q = 3;
        let groups = Groups::from_sizes(&[3, 3, 2]);
        let p = groups.p();
        let n = 18;
        let mut rng = Pcg::seeded(21);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        // Task-major Y: each task gets its own planted sparse model.
        let mut y = vec![0.0; n * q];
        for t in 0..q {
            let mut bt = vec![0.0; p];
            bt[t % p] = 1.5;
            bt[(t + 3) % p] = -1.0;
            let xb = x.matvec(&bt);
            for i in 0..n {
                y[t * n + i] = xb[i] + 0.01 * rng.normal();
            }
        }
        let w = groups.sqrt_size_weights();
        let pb = SglProblem::with_datafit(x, y, groups, 0.4, w, MultiTaskQuadratic::new(q));
        let lambda = 0.2 * pb.lambda_max();
        let opts = SolveOptions { tol: 1e-10, ..Default::default() };
        let res = solve(&pb, lambda, None, &opts);
        assert!(res.converged, "gap={}", res.gap);
        assert_eq!(res.beta.len(), p * q);
        // Screened features must be exactly zero rows; a no-screening
        // reference must agree that they are (numerically) inactive.
        let reference = solve(
            &pb,
            lambda,
            None,
            &SolveOptions { rule: RuleKind::None, tol: 1e-12, ..Default::default() },
        );
        for j in 0..p {
            if !res.active.feature[j] {
                for t in 0..q {
                    assert_eq!(res.beta[j * q + t], 0.0);
                    assert!(
                        reference.beta[j * q + t].abs() < 1e-6,
                        "screened feature {j} task {t} has ref beta {}",
                        reference.beta[j * q + t]
                    );
                }
            }
        }
    }

    #[test]
    fn multitask_all_rules_reach_same_objective() {
        use crate::norms::block::omega_rows;
        use crate::solver::datafit::MultiTaskQuadratic;
        let q = 2;
        let groups = Groups::from_sizes(&[4, 4]);
        let p = groups.p();
        let n = 16;
        let mut rng = Pcg::seeded(22);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        let w = groups.sqrt_size_weights();
        let pb =
            SglProblem::with_datafit(x.clone(), y, groups, 0.5, w, MultiTaskQuadratic::new(q));
        let lambda = 0.15 * pb.lambda_max();
        let mut objectives = Vec::new();
        for rule in RuleKind::all() {
            let opts = SolveOptions { rule, tol: 1e-10, ..Default::default() };
            let res = solve(&pb, lambda, None, &opts);
            assert!(res.converged, "{:?} gap={}", rule, res.gap);
            // Objective from scratch: Frobenius residual + row/group norms.
            let mut rss = 0.0;
            for t in 0..q {
                let bt: Vec<f64> = (0..p).map(|j| res.beta[j * q + t]).collect();
                let xb = x.matvec(&bt);
                for i in 0..n {
                    let r = pb.y[t * n + i] - xb[i];
                    rss += r * r;
                }
            }
            let obj = 0.5 * rss
                + lambda * omega_rows(&res.beta, q, &pb.groups, pb.tau, &pb.weights);
            objectives.push(obj);
        }
        for o in &objectives[1..] {
            assert!((o - objectives[0]).abs() < 1e-7, "{objectives:?}");
        }
    }

    #[test]
    fn lasso_special_case_matches_soft_threshold_on_orthogonal_design() {
        // Orthonormal X (identity): lasso solution = S_lambda(y).
        let n = 6;
        let x = Matrix::scaled_identity(n, 1.0);
        let y = vec![3.0, -2.0, 0.5, 0.0, 1.5, -4.0];
        let groups = Groups::uniform(n, 1);
        // weights sqrt(1) = 1; tau=1 => pure lasso.
        let pb = SglProblem::new(x, y.clone(), groups, 1.0);
        let lambda = 1.0;
        let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-12, ..Default::default() });
        for j in 0..n {
            let expect = crate::norms::prox::soft_threshold(y[j], lambda);
            assert!((res.beta[j] - expect).abs() < 1e-9, "j={j}");
        }
    }

    #[test]
    fn group_lasso_special_case_on_orthogonal_design() {
        // X = I, groups of 2, tau=0, w_g=1: solution = block-soft(y).
        let n = 6;
        let x = Matrix::scaled_identity(n, 1.0);
        let y = vec![3.0, 4.0, 0.1, 0.1, -1.0, 0.0];
        let groups = Groups::uniform(3, 2);
        let pb = SglProblem::with_weights(x, y.clone(), groups, 0.0, vec![1.0; 3]);
        let lambda = 1.0;
        let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-12, ..Default::default() });
        for (g, a, b) in pb.groups.iter() {
            let expect = crate::norms::prox::group_soft_threshold(&y[a..b], lambda);
            for (k, j) in (a..b).enumerate() {
                assert!((res.beta[j] - expect[k]).abs() < 1e-9, "g={g} j={j}");
            }
        }
    }
}
