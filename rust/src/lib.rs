//! # sgl-screening — GAP Safe Screening Rules for the Sparse-Group Lasso
//!
//! A production-oriented reproduction of *GAP Safe Screening Rules for
//! Sparse-Group Lasso* (Ndiaye, Fercoq, Gramfort, Salmon — NIPS 2016) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the solver framework: problem/dataset
//!   abstractions, the ISTA-BC coordinate-descent solver (Algorithm 2),
//!   the ε-norm dual-norm machinery (Algorithm 1), all five screening
//!   rules (GAP safe + the App. C baselines), path/CV runners, and the
//!   experiment drivers that regenerate every figure of the paper.
//! - **Layer 2/1 (build time, `python/compile/`)** — the masked ISTA epoch
//!   and screening computations expressed in JAX + Pallas, AOT-lowered to
//!   HLO text; [`runtime`] loads and executes those artifacts via PJRT so
//!   Python never runs on the solve path.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use sgl::data::synthetic::{generate, SyntheticConfig};
//! use sgl::solver::{cd, problem::SglProblem};
//!
//! let data = generate(&SyntheticConfig::small(42));
//! let pb = SglProblem::new(data.dataset.x, data.dataset.y, data.dataset.groups, 0.2);
//! let lambda = 0.1 * pb.lambda_max();
//! let res = cd::solve(&pb, lambda, None, &cd::SolveOptions::default());
//! assert!(res.converged);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod norms;
pub mod runtime;
pub mod screening;
pub mod solver;
pub mod util;
