//! Bench: the batched path engine (`solver::path::PathBatch`) vs the plain
//! sequential loop over the same jobs.
//!
//! Workload: the Fig. 2c comparison grid — every screening rule crossed
//! with several target accuracies, each job one full warm-started λ-path
//! on the synthetic design. `threads=1` is the sequential baseline;
//! `SGL_THREADS` (or all cores) is the batched runner. Besides wall-clock,
//! the bench verifies the two runs are *bit-identical* per job and that
//! all rules agree on the path objectives to 1e-7 at the tightest
//! tolerance (y is scaled to unit norm so that absolute objective budget
//! is meaningful).
//!
//! Default scale: p = 2000, T = 40 (seconds); `SGL_BENCH_SCALE=paper`
//! runs the full n=100, p=10000, T=100 instance.
//!
//! A second section benchmarks the **design backends**: the same
//! ~1%-density sparse problem solved through the dense `Matrix` and the
//! `CscMatrix` backend — identical λ-grid, identical rule — verifying the
//! objectives agree to 1e-7 while the CSC sweeps, which touch only stored
//! entries, win on wall-clock.
//!
//! A third section measures **single-path latency**: one active-heavy
//! p ≥ 5000 path solved with the serial cyclic sweep vs the intra-path
//! parallel sweep layer (`sweep = "parallel"`, `solver::sweep`). This is
//! the one axis `PathBatch` cannot touch (a single warm-started path has
//! no between-path parallelism). Objectives must agree to ≤ 1e-8 and,
//! on a multi-core host, the parallel sweep must win wall-clock.

use sgl::data::sparse::{self, SparseSyntheticConfig};
use sgl::data::synthetic::{generate, generate_multitask, SyntheticConfig};
use sgl::linalg::Design;
use sgl::norms::block::omega_rows;
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::datafit::MultiTaskQuadratic;
use sgl::solver::path::{solve_path_on_grid, PathBatch, PathBatchJob, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::sweep::SweepMode;
use sgl::util::json::Json;
use sgl::util::pool::{default_threads, resolve_threads};
use sgl::util::timer::Stopwatch;
use std::sync::Arc;

fn main() {
    let paper = std::env::var("SGL_BENCH_SCALE").as_deref() == Ok("paper");
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: if paper { 1000 } else { 200 },
        group_size: 10,
        gamma1: 10,
        gamma2: 4,
        seed: 42,
        ..Default::default()
    };
    let t_count = if paper { 100 } else { 40 };
    let delta = 3.0;
    let tau = 0.2;
    let tolerances = [1e-4, 1e-6, 1e-8];

    let d = generate(&cfg);
    // Unit-norm y: objective differences then compare directly against the
    // 1e-7 agreement budget, independent of the dataset's scale.
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    let pb = Arc::new(SglProblem::new(d.dataset.x, y, d.dataset.groups, tau));
    let lambdas = SglProblem::lambda_grid(pb.lambda_max(), delta, t_count);

    let mut batch = PathBatch::new();
    for &tol in &tolerances {
        for rule in RuleKind::all() {
            batch.push(PathBatchJob {
                pb: pb.clone(),
                lambdas: Some(lambdas.clone()),
                opts: PathOptions {
                    delta,
                    t_count,
                    solve: SolveOptions { rule, tol, record_history: false, ..Default::default() },
                },
                tau_override: None,
                label: format!("{}@{tol:.0e}", rule.name()),
            });
        }
    }
    println!(
        "== bench_path_batch: {} path jobs ({} rules x {} tols), n={}, p={}, T={t_count} ==\n",
        batch.len(),
        RuleKind::all().len(),
        tolerances.len(),
        cfg.n,
        cfg.p()
    );

    let threads = default_threads().max(2);
    let sw = Stopwatch::start();
    let serial = batch.run(1);
    let t_serial = sw.elapsed_s();
    let sw = Stopwatch::start();
    let threaded = batch.run(threads);
    let t_threaded = sw.elapsed_s();
    println!("sequential loop (threads=1):   {t_serial:>8.3}s");
    println!(
        "batched runner  (threads={threads}):   {t_threaded:>8.3}s  ({:.2}x speedup)",
        t_serial / t_threaded.max(1e-12)
    );

    // Determinism: threading must not change a single coefficient.
    let mut identical = true;
    for (a, b) in serial.iter().zip(&threaded) {
        for (ra, rb) in a.results.iter().zip(&b.results) {
            identical &= ra.beta == rb.beta;
        }
    }
    println!("serial vs threaded coefficients bit-identical: {identical}");
    assert!(identical, "threading changed solver output");

    // Objective agreement across all rules at the tightest tolerance.
    let objective = |lambda: f64, beta: &[f64]| {
        let xb = pb.x.matvec(beta);
        let r2: f64 = pb.y.iter().zip(&xb).map(|(yi, v)| (yi - v) * (yi - v)).sum();
        0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
    };
    let n_rules = RuleKind::all().len();
    let tight_base = (tolerances.len() - 1) * n_rules; // RuleKind::None @ 1e-8
    let mut max_div = 0.0_f64;
    for r in 1..n_rules {
        for (i, &lambda) in lambdas.iter().enumerate() {
            let a = objective(lambda, &serial[tight_base].results[i].beta);
            let b = objective(lambda, &serial[tight_base + r].results[i].beta);
            max_div = max_div.max((a - b).abs());
        }
    }
    println!("max objective divergence across rules @1e-8: {max_div:.2e}");
    assert!(max_div <= 1e-7, "rules disagree beyond budget: {max_div:.2e}");

    println!("\nlabel,seconds,epochs,converged  (threaded run)");
    let mut jobs_json = Vec::new();
    for (job, path) in batch.jobs().iter().zip(&threaded) {
        println!(
            "{},{:.4},{},{}",
            job.label,
            path.total_s,
            path.total_epochs(),
            path.all_converged()
        );
        jobs_json.push(
            Json::obj()
                .with("label", job.label.clone())
                .with("seconds", path.total_s)
                .with("epochs", path.total_epochs())
                .with("converged", path.all_converged()),
        );
    }

    let batch_json = Json::obj()
        .with("jobs", batch.len())
        .with("threads", threads)
        .with("serial_s", t_serial)
        .with("threaded_s", t_threaded)
        .with("bit_identical", identical)
        .with("max_objective_divergence", max_div)
        .with("per_job", Json::Arr(jobs_json));
    let backends_json = bench_backends(paper);
    let latency_json = bench_single_path_latency(paper);
    let multitask_json = bench_multitask(paper);

    // Machine-readable summary next to the printed report, for tracking
    // bench results across commits.
    let out = Json::obj()
        .with("kernels", sgl::linalg::simd::effective().name())
        .with("scale", if paper { "paper" } else { "small" })
        .with("path_batch", batch_json)
        .with("backends", backends_json)
        .with("single_path_latency", latency_json)
        .with("multitask", multitask_json);
    std::fs::write("BENCH_path_batch.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_path_batch.json");
}

/// Multi-task paths (`datafit=multitask`): the q-column quadratic
/// workload — GAP-safe screening vs the unscreened baseline on one
/// grid (objectives must agree), then the batch engine on matrix-valued
/// jobs (threading must stay bit-identical).
fn bench_multitask(paper: bool) -> Json {
    let q = if paper { 8 } else { 4 };
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: if paper { 500 } else { 150 },
        group_size: 10,
        gamma1: 10,
        gamma2: 4,
        seed: 77,
        ..Default::default()
    };
    let d = generate_multitask(&cfg, q);
    // Unit-norm Y (all q columns jointly) so the 1e-7 agreement budget
    // is absolute, matching the scalar sections.
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    let weights = d.dataset.groups.sqrt_size_weights();
    let pb = Arc::new(SglProblem::with_datafit(
        d.dataset.x,
        y,
        d.dataset.groups,
        0.2,
        weights,
        MultiTaskQuadratic::new(q),
    ));
    let t_count = if paper { 60 } else { 30 };
    let lambdas = lambda_grid(pb.lambda_max(), 2.0, t_count);
    println!(
        "\n== multi-task paths (datafit=multitask): n={}, p={}, q={q}, T={t_count} ==",
        pb.n(),
        pb.p()
    );

    let opts = |rule| PathOptions {
        delta: 2.0,
        t_count,
        solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
    };
    let sw = Stopwatch::start();
    let base = solve_path_on_grid(pb.as_ref(), &lambdas, &opts(RuleKind::None));
    let t_none = sw.elapsed_s();
    let sw = Stopwatch::start();
    let screened = solve_path_on_grid(pb.as_ref(), &lambdas, &opts(RuleKind::GapSafeSeq));
    let t_gap = sw.elapsed_s();
    assert!(base.all_converged(), "unscreened multi-task path failed to converge");
    assert!(screened.all_converged(), "screened multi-task path failed to converge");

    // ½‖Y − XB‖_F² + λΩ(B) over the task-major response and
    // feature-major coefficients.
    let objective = |lambda: f64, beta: &[f64]| {
        let n = pb.n();
        let mut r2 = 0.0;
        for t in 0..q {
            let bt: Vec<f64> = (0..pb.p()).map(|j| beta[j * q + t]).collect();
            let xb = pb.x.matvec(&bt);
            r2 += pb.y[t * n..(t + 1) * n]
                .iter()
                .zip(&xb)
                .map(|(yi, v)| (yi - v) * (yi - v))
                .sum::<f64>();
        }
        0.5 * r2 + lambda * omega_rows(beta, q, &pb.groups, pb.tau, &pb.weights)
    };
    let mut max_div = 0.0_f64;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let a = objective(lambda, &base.results[i].beta);
        let b = objective(lambda, &screened.results[i].beta);
        max_div = max_div.max((a - b).abs());
    }
    println!("unscreened path   (T={t_count} @1e-8): {t_none:>8.3}s");
    println!(
        "gap_safe_seq path (T={t_count} @1e-8): {t_gap:>8.3}s  ({:.2}x speedup)",
        t_none / t_gap.max(1e-12)
    );
    println!("max objective divergence none vs gap_safe_seq: {max_div:.2e}");
    assert!(max_div <= 1e-7, "screening changed the multi-task answer: {max_div:.2e}");

    let mut batch = PathBatch::new();
    for rule in [RuleKind::GapSafe, RuleKind::GapSafeSeq] {
        batch.push(PathBatchJob {
            pb: pb.clone(),
            lambdas: Some(lambdas.clone()),
            opts: opts(rule),
            tau_override: None,
            label: format!("{}@mt{q}", rule.name()),
        });
    }
    let threads = default_threads().max(2);
    let sw = Stopwatch::start();
    let serial = batch.run(1);
    let t_serial = sw.elapsed_s();
    let sw = Stopwatch::start();
    let threaded = batch.run(threads);
    let t_threaded = sw.elapsed_s();
    let mut identical = true;
    for (a, b) in serial.iter().zip(&threaded) {
        for (ra, rb) in a.results.iter().zip(&b.results) {
            identical &= ra.beta == rb.beta;
        }
    }
    println!(
        "multi-task batch: serial {t_serial:.3}s vs threaded {t_threaded:.3}s \
         (threads={threads}), bit-identical: {identical}"
    );
    assert!(identical, "threading changed multi-task solver output");

    Json::obj()
        .with("datafit", "multitask")
        .with("tasks", q)
        .with("p", pb.p())
        .with("none_s", t_none)
        .with("gap_safe_seq_s", t_gap)
        .with("max_objective_divergence", max_div)
        .with("batch_serial_s", t_serial)
        .with("batch_threaded_s", t_threaded)
        .with("bit_identical", identical)
}

/// Dense vs CSC on a ~1%-density design: same data, same λ-grid, same
/// sequential GAP-safe rule; only the backend differs.
fn bench_backends(paper: bool) -> Json {
    let cfg = SparseSyntheticConfig {
        n: 100,
        n_groups: if paper { 2000 } else { 500 },
        group_size: 10,
        density: 0.01,
        gamma1: 10,
        gamma2: 4,
        seed: 7,
        ..Default::default()
    };
    let d = sparse::generate(&cfg);
    // Unit-norm y so the 1e-7 agreement budget is absolute.
    let y_norm = d.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.y.iter().map(|v| v / y_norm).collect();
    let x_dense = d.x.to_dense();
    let pb_csc = SglProblem::new(d.x.clone(), y.clone(), d.groups.clone(), 0.2);
    let pb_dense = SglProblem::new(x_dense, y, d.groups.clone(), 0.2);
    println!(
        "\n== backend comparison: n={}, p={}, density {:.2}% (nnz={}) ==",
        cfg.n,
        cfg.p(),
        100.0 * pb_csc.x.density(),
        pb_csc.x.nnz()
    );

    // Identical grid for both backends (from the dense λ_max).
    let t_count = if paper { 60 } else { 30 };
    let lambdas = lambda_grid(pb_dense.lambda_max(), 2.0, t_count);
    let opts = PathOptions {
        delta: 2.0,
        t_count,
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 1e-8,
            record_history: false,
            ..Default::default()
        },
    };

    let sw = Stopwatch::start();
    let dense_path = solve_path_on_grid(&pb_dense, &lambdas, &opts);
    let t_dense = sw.elapsed_s();
    let sw = Stopwatch::start();
    let csc_path = solve_path_on_grid(&pb_csc, &lambdas, &opts);
    let t_csc = sw.elapsed_s();

    assert!(dense_path.all_converged(), "dense backend failed to converge");
    assert!(csc_path.all_converged(), "csc backend failed to converge");

    // Objective agreement across backends at every grid point.
    let objective = |lambda: f64, beta: &[f64]| {
        let xb = pb_dense.x.matvec(beta);
        let r2: f64 =
            pb_dense.y.iter().zip(&xb).map(|(yi, v)| (yi - v) * (yi - v)).sum();
        0.5 * r2 + lambda * omega(beta, &pb_dense.groups, pb_dense.tau, &pb_dense.weights)
    };
    let mut max_div = 0.0_f64;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let a = objective(lambda, &dense_path.results[i].beta);
        let b = objective(lambda, &csc_path.results[i].beta);
        max_div = max_div.max((a - b).abs());
    }
    println!("dense path (T={t_count}, gap_safe_seq @1e-8): {t_dense:>8.3}s");
    println!(
        "csc path   (T={t_count}, gap_safe_seq @1e-8): {t_csc:>8.3}s  ({:.2}x speedup)",
        t_dense / t_csc.max(1e-12)
    );
    println!("max objective divergence dense vs csc: {max_div:.2e}");
    assert!(max_div <= 1e-7, "backends disagree beyond budget: {max_div:.2e}");
    assert!(
        t_csc < t_dense,
        "CSC backend should win on a {:.2}%-density design ({t_csc:.3}s vs {t_dense:.3}s)",
        100.0 * pb_csc.x.density()
    );
    Json::obj()
        .with("p", pb_csc.p())
        .with("density", pb_csc.x.density())
        .with("dense_s", t_dense)
        .with("csc_s", t_csc)
        .with("max_objective_divergence", max_div)
}

/// Single-path latency: serial cyclic sweep vs the intra-path parallel
/// sweep layer on one active-heavy p ≥ 5000 path.
fn bench_single_path_latency(paper: bool) -> Json {
    let cfg = SyntheticConfig {
        n: if paper { 200 } else { 150 },
        n_groups: if paper { 1000 } else { 550 },
        group_size: 10,
        // Many planted groups + a deep grid: the λ tail keeps most of the
        // design active, so the per-epoch group sweep dominates — the
        // regime the parallel sweep targets.
        gamma1: 40,
        gamma2: 6,
        seed: 1234,
        ..Default::default()
    };
    let d = generate(&cfg);
    // Unit-norm y: with tol = 5e-9 both runs end within 5e-9 of the
    // optimum, so the ≤ 1e-8 objective-agreement budget below is implied
    // by convergence — and still asserted directly.
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    let pb = SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2);
    let t_count = if paper { 10 } else { 8 };
    let lambdas = lambda_grid(pb.lambda_max(), 2.0, t_count);
    let opts = |sweep| PathOptions {
        delta: 2.0,
        t_count,
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 5e-9,
            record_history: false,
            sweep,
            sweep_threads: 0, // auto
            ..Default::default()
        },
    };
    let threads = resolve_threads(0);
    println!(
        "\n== single-path latency: n={}, p={}, T={t_count}, gap_safe_seq @5e-9, \
         sweep_threads={threads} ==",
        pb.n(),
        pb.p()
    );

    let sw = Stopwatch::start();
    let serial = solve_path_on_grid(&pb, &lambdas, &opts(SweepMode::Serial));
    let t_serial = sw.elapsed_s();
    let sw = Stopwatch::start();
    let parallel = solve_path_on_grid(&pb, &lambdas, &opts(SweepMode::Parallel));
    let t_parallel = sw.elapsed_s();
    assert!(serial.all_converged(), "serial sweep failed to converge");
    assert!(parallel.all_converged(), "parallel sweep failed to converge");

    let objective = |lambda: f64, beta: &[f64]| {
        let xb = pb.x.matvec(beta);
        let r2: f64 = pb.y.iter().zip(&xb).map(|(yi, v)| (yi - v) * (yi - v)).sum();
        0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
    };
    let mut max_div = 0.0_f64;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let a = objective(lambda, &serial.results[i].beta);
        let b = objective(lambda, &parallel.results[i].beta);
        max_div = max_div.max((a - b).abs());
    }
    println!(
        "serial sweep:   {t_serial:>8.3}s  ({} epochs)",
        serial.total_epochs()
    );
    println!(
        "parallel sweep: {t_parallel:>8.3}s  ({} epochs, {:.2}x speedup)",
        parallel.total_epochs(),
        t_serial / t_parallel.max(1e-12)
    );
    println!("max objective divergence serial vs parallel: {max_div:.2e}");
    assert!(
        max_div <= 1e-8,
        "sweep modes disagree beyond budget: {max_div:.2e}"
    );
    if threads >= 2 {
        assert!(
            t_parallel < t_serial,
            "parallel sweep should win single-path latency on {threads} threads \
             ({t_parallel:.3}s vs {t_serial:.3}s)"
        );
    } else {
        println!("single hardware thread: skipping the wall-clock assertion");
    }
    Json::obj()
        .with("p", pb.p())
        .with("sweep_threads", threads)
        .with("serial_s", t_serial)
        .with("parallel_s", t_parallel)
        .with("max_objective_divergence", max_div)
}
