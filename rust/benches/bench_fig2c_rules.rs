//! Bench: Figure 2c — time to solve the synthetic λ-path to a prescribed
//! duality gap, for every screening rule.
//!
//! Default scale is half the paper's feature count (p = 5000, T = 50) so
//! `cargo bench` finishes in minutes; set `SGL_BENCH_SCALE=paper` for the
//! full n=100, p=10000, T=100 instance of §7.1.
//!
//! Expected *shape* (paper Fig. 2c): at loose tolerances the rules tie;
//! as the tolerance tightens, GAP safe pulls ahead of DST3/dynamic/static,
//! with a multi-x gap over no-screening at 1e-8.

use sgl::coordinator::jobs::RuleComparisonJob;
use sgl::coordinator::report::render_rule_timings;
use sgl::data::synthetic::SyntheticConfig;
use sgl::experiments::fig2;
use sgl::linalg::simd;
use sgl::util::json::Json;
use sgl::util::pool::default_threads;

fn main() {
    let paper = std::env::var("SGL_BENCH_SCALE").as_deref() == Ok("paper");
    let cfg = if paper {
        SyntheticConfig::default() // n=100, p=10000, rho=0.5, g1=10, g2=4
    } else {
        SyntheticConfig {
            n: 100,
            n_groups: 500,
            group_size: 10,
            gamma1: 10,
            gamma2: 4,
            seed: 42,
            ..Default::default()
        }
    };
    let t_count = if paper { 100 } else { 50 };
    let tau = 0.2;
    println!(
        "== bench_fig2c: synthetic path (n={}, p={}, T={t_count}, tau={tau}) ==",
        cfg.n,
        cfg.p()
    );
    println!("rules x tolerances, each = one full warm-started path\n");

    let job = RuleComparisonJob {
        tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
        delta: 3.0,
        t_count,
        // Timing-grade: one job at a time, no core contention.
        serial_timing: true,
        ..Default::default()
    };
    let timings = fig2::rule_timings(&cfg, tau, &job, default_threads());
    println!("{}", render_rule_timings(&timings));

    // Machine-readable rows for EXPERIMENTS.md.
    println!("rule,tol,seconds,epochs,converged");
    for t in &timings {
        println!(
            "{},{:.0e},{:.4},{},{}",
            t.rule.name(),
            t.tol,
            t.seconds,
            t.total_epochs,
            t.converged
        );
    }

    let rows: Vec<Json> = timings
        .iter()
        .map(|t| {
            Json::obj()
                .with("rule", t.rule.name())
                .with("tol", t.tol)
                .with("seconds", t.seconds)
                .with("epochs", t.total_epochs as f64)
                .with("converged", t.converged)
        })
        .collect();
    let out = Json::obj()
        .with("bench", "fig2c_rules")
        .with("kernels", simd::effective().name())
        .with("scale", if paper { "paper" } else { "small" })
        .with("n", cfg.n as f64)
        .with("p", cfg.p() as f64)
        .with("t_count", t_count as f64)
        .with("timings", Json::Arr(rows));
    std::fs::write("BENCH_fig2c_rules.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_fig2c_rules.json");
}
