//! Bench: Figure 3b — time to solve the climate λ-path (δ = 2.5, τ★ = 0.4)
//! to a prescribed duality gap, per screening rule, on the simulated
//! NCEP/NCAR dataset (DESIGN.md §Substitutions).
//!
//! Default grid is 24x12 (p = 2016); `SGL_BENCH_SCALE=paper` uses the
//! 37x18 default (p = 4662, n = 814) — the full simulated instance.

use sgl::coordinator::jobs::RuleComparisonJob;
use sgl::coordinator::report::render_rule_timings;
use sgl::data::climate::ClimateConfig;
use sgl::experiments::fig3;
use sgl::linalg::simd;
use sgl::util::json::Json;
use sgl::util::pool::default_threads;

fn main() {
    let paper = std::env::var("SGL_BENCH_SCALE").as_deref() == Ok("paper");
    let cfg = if paper {
        ClimateConfig::default()
    } else {
        ClimateConfig { grid_lon: 24, grid_lat: 12, n_months: 400, ..Default::default() }
    };
    let t_count = if paper { 100 } else { 50 };
    println!(
        "== bench_fig3b: simulated climate {}x{} grid, n={}, p={}, T={t_count} ==",
        cfg.grid_lon,
        cfg.grid_lat,
        cfg.n_months,
        cfg.p()
    );
    let data = fig3::prepared_data(&cfg);
    let job = RuleComparisonJob {
        tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
        delta: 2.5, // the paper's climate-path choice
        t_count,
        // Timing-grade: one job at a time, no core contention.
        serial_timing: true,
        ..Default::default()
    };
    let timings = fig3::rule_timings(&data, 0.4, &job, default_threads());
    println!("{}", render_rule_timings(&timings));

    println!("rule,tol,seconds,epochs,converged");
    for t in &timings {
        println!(
            "{},{:.0e},{:.4},{},{}",
            t.rule.name(),
            t.tol,
            t.seconds,
            t.total_epochs,
            t.converged
        );
    }

    let rows: Vec<Json> = timings
        .iter()
        .map(|t| {
            Json::obj()
                .with("rule", t.rule.name())
                .with("tol", t.tol)
                .with("seconds", t.seconds)
                .with("epochs", t.total_epochs as f64)
                .with("converged", t.converged)
        })
        .collect();
    let out = Json::obj()
        .with("bench", "fig3b_climate")
        .with("kernels", simd::effective().name())
        .with("scale", if paper { "paper" } else { "small" })
        .with("n", cfg.n_months as f64)
        .with("p", cfg.p() as f64)
        .with("t_count", t_count as f64)
        .with("timings", Json::Arr(rows));
    std::fs::write("BENCH_fig3b_climate.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_fig3b_climate.json");
}
