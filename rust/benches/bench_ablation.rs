//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **f_ce** (gap-evaluation frequency, paper §6 uses 10): trade-off
//!    between screening opportunity and `O(np)` gap-eval overhead;
//! 2. **warm starts** along the λ-path vs cold solves;
//! 3. **strong rules (unsafe, KKT-checked) vs GAP safe vs both combined**
//!    — the working-set-style comparison the paper discusses in §1;
//! 4. **dual-norm evaluation** inside the solve: Algorithm 1 vs the naive
//!    quadratic scan (end-to-end impact, complementing bench_dual_norm);
//! 5. **inner solvers**: cyclic BCD (Alg. 2) vs masked ISTA vs FISTA at a
//!    single λ — CD is the paper's choice and wins on epochs.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::simd;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::path::{solve_path_on_grid, PathOptions};
use sgl::solver::problem::SglProblem;
use sgl::solver::strong::solve_path_strong;
use sgl::util::json::Json;
use sgl::util::timer::Stopwatch;

fn problem() -> SglProblem {
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: 300,
        group_size: 10,
        gamma1: 8,
        gamma2: 4,
        seed: 42,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.2)
}

fn main() {
    let pb = problem();
    let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 3.0, 40);
    println!("== bench_ablation (n=100, p=3000, T=40, tol=1e-8) ==\n");

    // ---- 1. f_ce sweep
    println!("f_ce sweep (gap_safe):");
    let mut fce_rows: Vec<Json> = Vec::new();
    for fce in [1usize, 5, 10, 20, 50] {
        let opts = PathOptions {
            delta: 3.0,
            t_count: lambdas.len(),
            solve: SolveOptions {
                tol: 1e-8,
                fce,
                rule: RuleKind::GapSafe,
                record_history: false,
                ..Default::default()
            },
        };
        let path = solve_path_on_grid(&pb, &lambdas, &opts);
        println!(
            "  fce={fce:>3}: {:>8.3}s  epochs={:>7}  gap_evals={:>6}  converged={}",
            path.total_s,
            path.total_epochs(),
            path.results.iter().map(|r| r.gap_evals).sum::<usize>(),
            path.all_converged()
        );
        fce_rows.push(
            Json::obj()
                .with("fce", fce as f64)
                .with("seconds", path.total_s)
                .with("epochs", path.total_epochs() as f64)
                .with("converged", path.all_converged()),
        );
    }

    // ---- 2. warm vs cold
    println!("\nwarm starts vs cold solves (gap_safe, fce=10):");
    let opts = PathOptions {
        delta: 3.0,
        t_count: lambdas.len(),
        solve: SolveOptions { tol: 1e-8, record_history: false, ..Default::default() },
    };
    let warm = solve_path_on_grid(&pb, &lambdas, &opts);
    let sw = Stopwatch::start();
    let mut cold_epochs = 0usize;
    for &l in &lambdas {
        let res = sgl::solver::cd::solve(&pb, l, None, &opts.solve);
        cold_epochs += res.epochs;
    }
    let cold_s = sw.elapsed_s();
    println!("  warm: {:>8.3}s  epochs={}", warm.total_s, warm.total_epochs());
    println!("  cold: {:>8.3}s  epochs={}", cold_s, cold_epochs);
    let warm_cold_json = Json::obj()
        .with("warm_s", warm.total_s)
        .with("warm_epochs", warm.total_epochs() as f64)
        .with("cold_s", cold_s)
        .with("cold_epochs", cold_epochs as f64);

    // ---- 3. strong rules vs gap safe vs both
    println!("\nworking sets (strong rules, unsafe + KKT recovery) vs GAP safe:");
    let mut strong_rows: Vec<Json> = Vec::new();
    for (name, rule, use_strong) in [
        ("gap_safe only", RuleKind::GapSafe, false),
        ("strong only (none inside)", RuleKind::None, true),
        ("strong + gap_safe inside", RuleKind::GapSafe, true),
    ] {
        let solve_opts =
            SolveOptions { tol: 1e-8, rule, record_history: false, ..Default::default() };
        if use_strong {
            let (results, stats, secs) = solve_path_strong(&pb, &lambdas, &solve_opts);
            println!(
                "  {name:<28}: {secs:>8.3}s  subsolves={} violations={} kept_avg={:.1}",
                stats.subsolves,
                stats.violations,
                stats.kept_groups_initial as f64 / results.len() as f64
            );
            strong_rows.push(Json::obj().with("variant", name).with("seconds", secs));
        } else {
            let path = solve_path_on_grid(
                &pb,
                &lambdas,
                &PathOptions { delta: 3.0, t_count: lambdas.len(), solve: solve_opts },
            );
            println!(
                "  {name:<28}: {:>8.3}s  epochs={}",
                path.total_s,
                path.total_epochs()
            );
            strong_rows.push(Json::obj().with("variant", name).with("seconds", path.total_s));
        }
    }

    // ---- 5. inner solvers at a single lambda
    println!("\ninner solvers at lambda = lambda_max/10 (tol 1e-8, rule gap_safe):");
    let solvers_json;
    {
        let lambda = 0.1 * pb.lambda_max();
        let opts = SolveOptions {
            tol: 1e-8,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let a = sgl::solver::cd::solve(&pb, lambda, None, &opts);
        let ta = sw.elapsed_s();
        let sw = Stopwatch::start();
        let b = sgl::solver::ista::solve_ista(&pb, lambda, None, &opts);
        let tb = sw.elapsed_s();
        let sw = Stopwatch::start();
        let c = sgl::solver::fista::solve_fista(&pb, lambda, None, &opts);
        let tc = sw.elapsed_s();
        println!("  cd (Alg. 2): {ta:>8.3}s epochs={:>7} converged={}", a.epochs, a.converged);
        println!("  ista       : {tb:>8.3}s epochs={:>7} converged={}", b.epochs, b.converged);
        println!("  fista      : {tc:>8.3}s epochs={:>7} converged={}", c.epochs, c.converged);
        solvers_json = Json::obj()
            .with("cd_s", ta)
            .with("ista_s", tb)
            .with("fista_s", tc)
            .with("cd_epochs", a.epochs as f64)
            .with("ista_epochs", b.epochs as f64)
            .with("fista_epochs", c.epochs as f64);
    }

    // ---- 4. dual norm inside the gap eval: Algorithm 1 vs naive
    println!("\ndual-norm evaluation inside one gap check (p=3000):");
    let beta = vec![0.01; pb.p()];
    let xb = pb.x.matvec(&beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let xt = pb.x.tmatvec(&rho);
    let sw = Stopwatch::start();
    for _ in 0..200 {
        std::hint::black_box(sgl::norms::sgl::omega_dual(
            &xt,
            &pb.groups,
            pb.tau,
            &pb.weights,
        ));
    }
    let alg1 = sw.elapsed_s() / 200.0;
    let sw = Stopwatch::start();
    for _ in 0..200 {
        std::hint::black_box(sgl::norms::sgl::omega_dual_naive(
            &xt,
            &pb.groups,
            pb.tau,
            &pb.weights,
        ));
    }
    let naive = sw.elapsed_s() / 200.0;
    println!("  alg1 : {:>10.2} us", alg1 * 1e6);
    println!("  naive: {:>10.2} us ({:.1}x slower)", naive * 1e6, naive / alg1);

    let out = Json::obj()
        .with("bench", "ablation")
        .with("kernels", simd::effective().name())
        .with("n", pb.n() as f64)
        .with("p", pb.p() as f64)
        .with("fce_sweep", Json::Arr(fce_rows))
        .with("warm_vs_cold", warm_cold_json)
        .with("working_sets", Json::Arr(strong_rows))
        .with("inner_solvers", solvers_json)
        .with("dual_norm", Json::obj().with("alg1_s", alg1).with("naive_s", naive));
    std::fs::write("BENCH_ablation.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_ablation.json");
}
