//! Bench: solver hot paths in isolation — CD epoch cost vs active-set
//! size, gap-evaluation (dual norm) cost, prox throughput, and the
//! screening-application overhead. These are the quantities the §Perf
//! iteration log in EXPERIMENTS.md tracks.
//!
//! Writes `BENCH_solver_core.json` (median seconds per case, plus the
//! kernel-policy shootout) so the perf trajectory persists across
//! commits; the shootout times the p=5000 dense correlation `Xᵀu` under
//! both kernel policies and asserts the SIMD path does not lose.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::simd;
use sgl::norms::prox::sgl_prox_inplace;
use sgl::screening::{apply_sphere, ActiveSet, RuleKind, Sphere};
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::duality::DualSnapshot;
use sgl::solver::problem::SglProblem;
use sgl::util::json::Json;
use sgl::util::rng::Pcg;
use sgl::util::timer::{bench, black_box, BenchConfig, BenchResult};

fn problem() -> SglProblem {
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: 500,
        group_size: 10,
        gamma1: 10,
        gamma2: 4,
        seed: 42,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.2)
}

fn record(cases: &mut Vec<Json>, r: &BenchResult) {
    println!("{r}");
    cases.push(
        Json::obj()
            .with("name", r.name.as_str())
            .with("median_s", r.times.median)
            .with("mean_s", r.times.mean)
            .with("iters", r.times.n as f64),
    );
}

/// Scalar vs SIMD on the dot-heavy dense path: the full-height
/// correlation `Xᵀu` over all p=5000 columns, timed under each policy
/// via the explicit `dot_with` kernels (no dependence on the process
/// global, so the rest of the bench is unaffected).
fn kernel_shootout(pb: &SglProblem, cfg: BenchConfig) -> Json {
    let mut rng = Pcg::seeded(7);
    let u = rng.normal_vec(pb.n());
    let p = pb.p();
    let mut out = vec![0.0; p];
    let mut run = |simd_on: bool| {
        bench(
            &format!("X^T*u p={p} kernels={}", if simd_on { "simd" } else { "scalar" }),
            cfg,
            |_| {
                for (j, o) in out.iter_mut().enumerate() {
                    *o = simd::dot_with(pb.x.col(j), black_box(&u), simd_on);
                }
                black_box(&out);
            },
        )
    };
    let scalar = run(false);
    let fast = run(true);
    println!("{scalar}");
    println!("{fast}");
    let ratio = scalar.times.median / fast.times.median;
    println!("  simd speedup over scalar: {ratio:.2}x (lanes={})", simd::lanes());
    // The SIMD kernels must at least hold the line on a dot-heavy dense
    // workload. 10% slack absorbs shared-runner timing noise; a real
    // regression (reassociation gone wrong, panel sizing off) blows far
    // past it.
    if simd::lanes() >= 2 {
        assert!(
            fast.times.median <= scalar.times.median * 1.10,
            "simd dot lost to scalar: {:.3}us vs {:.3}us",
            fast.times.median * 1e6,
            scalar.times.median * 1e6
        );
    }
    Json::obj()
        .with("p", p as f64)
        .with("n", pb.n() as f64)
        .with("lanes", simd::lanes() as f64)
        .with("scalar_median_s", scalar.times.median)
        .with("simd_median_s", fast.times.median)
        .with("speedup", ratio)
}

fn main() {
    println!("== bench_solver_core (n=100, p=5000, 500 groups) ==\n");
    let pb = problem();
    let lambda = 0.1 * pb.lambda_max();
    let cfg = BenchConfig { warmup_iters: 2, iters: 12, max_seconds: 30.0 };
    let mut cases: Vec<Json> = Vec::new();

    // ---- full solves at two tolerances, with/without screening
    for (name, rule, tol) in [
        ("solve gap_safe 1e-6", RuleKind::GapSafe, 1e-6),
        ("solve none     1e-6", RuleKind::None, 1e-6),
        ("solve gap_safe 1e-8", RuleKind::GapSafe, 1e-8),
        ("solve none     1e-8", RuleKind::None, 1e-8),
    ] {
        let opts = SolveOptions { rule, tol, record_history: false, ..Default::default() };
        let r = bench(name, cfg, |_| {
            black_box(solve(&pb, lambda, None, &opts));
        });
        record(&mut cases, &r);
    }

    // ---- gap evaluation (X^T rho + dual norm) on the full problem
    let beta = vec![0.01; pb.p()];
    let xb = pb.x.matvec(&beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let r = bench("dual snapshot (gap eval)", cfg, |_| {
        black_box(DualSnapshot::compute(&pb, &beta, &rho, lambda));
    });
    record(&mut cases, &r);

    // ---- screening application given a snapshot
    let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
    let sphere = Sphere { xt_center: snap.xt_theta.clone(), radius: snap.radius };
    let r = bench("apply_sphere (all groups)", cfg, |_| {
        let mut active = ActiveSet::full(&pb.groups);
        let mut b = beta.clone();
        let mut rr = rho.clone();
        black_box(apply_sphere(&pb, &sphere, &mut active, &mut b, &mut rr));
    });
    record(&mut cases, &r);

    // ---- prox throughput
    let mut rng = Pcg::seeded(1);
    let mut blocks: Vec<Vec<f64>> = (0..500).map(|_| rng.normal_vec(10)).collect();
    let r = bench("sgl_prox x500 groups of 10", cfg, |_| {
        for b in blocks.iter_mut() {
            sgl_prox_inplace(b, 0.1, 0.2);
        }
        black_box(&blocks);
    });
    record(&mut cases, &r);

    // ---- matvec kernels
    let v = rng.normal_vec(pb.p());
    let mut out_n = vec![0.0; pb.n()];
    let r = bench("X*v (dense matvec)", cfg, |_| {
        pb.x.matvec_into(black_box(&v), &mut out_n);
        black_box(&out_n);
    });
    record(&mut cases, &r);
    let u = rng.normal_vec(pb.n());
    let mut out_p = vec![0.0; pb.p()];
    let r = bench("X^T*u (correlation)", cfg, |_| {
        pb.x.tmatvec_into(black_box(&u), &mut out_p);
        black_box(&out_p);
    });
    record(&mut cases, &r);

    // ---- scalar-vs-SIMD shootout on the p=5000 dense correlation
    println!("\n-- kernel policy shootout (explicit dot_with, both policies) --");
    let shootout = kernel_shootout(&pb, cfg);

    let out = Json::obj()
        .with("bench", "solver_core")
        .with("kernels", simd::effective().name())
        .with("n", pb.n() as f64)
        .with("p", pb.p() as f64)
        .with("cases", Json::Arr(cases))
        .with("kernel_shootout", shootout);
    std::fs::write("BENCH_solver_core.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_solver_core.json");
}
