//! Bench: solver hot paths in isolation — CD epoch cost vs active-set
//! size, gap-evaluation (dual norm) cost, prox throughput, and the
//! screening-application overhead. These are the quantities the §Perf
//! iteration log in EXPERIMENTS.md tracks.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::norms::prox::sgl_prox_inplace;
use sgl::screening::{apply_sphere, ActiveSet, RuleKind, Sphere};
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::duality::DualSnapshot;
use sgl::solver::problem::SglProblem;
use sgl::util::rng::Pcg;
use sgl::util::timer::{bench, black_box, BenchConfig};

fn problem() -> SglProblem {
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: 500,
        group_size: 10,
        gamma1: 10,
        gamma2: 4,
        seed: 42,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.2)
}

fn main() {
    println!("== bench_solver_core (n=100, p=5000, 500 groups) ==\n");
    let pb = problem();
    let lambda = 0.1 * pb.lambda_max();
    let cfg = BenchConfig { warmup_iters: 2, iters: 12, max_seconds: 30.0 };

    // ---- full solves at two tolerances, with/without screening
    for (name, rule, tol) in [
        ("solve gap_safe 1e-6", RuleKind::GapSafe, 1e-6),
        ("solve none     1e-6", RuleKind::None, 1e-6),
        ("solve gap_safe 1e-8", RuleKind::GapSafe, 1e-8),
        ("solve none     1e-8", RuleKind::None, 1e-8),
    ] {
        let opts = SolveOptions { rule, tol, record_history: false, ..Default::default() };
        let r = bench(name, cfg, |_| {
            black_box(solve(&pb, lambda, None, &opts));
        });
        println!("{r}");
    }

    // ---- gap evaluation (X^T rho + dual norm) on the full problem
    let beta = vec![0.01; pb.p()];
    let xb = pb.x.matvec(&beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let r = bench("dual snapshot (gap eval)", cfg, |_| {
        black_box(DualSnapshot::compute(&pb, &beta, &rho, lambda));
    });
    println!("{r}");

    // ---- screening application given a snapshot
    let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
    let sphere = Sphere { xt_center: snap.xt_theta.clone(), radius: snap.radius };
    let r = bench("apply_sphere (all groups)", cfg, |_| {
        let mut active = ActiveSet::full(&pb.groups);
        let mut b = beta.clone();
        let mut rr = rho.clone();
        black_box(apply_sphere(&pb, &sphere, &mut active, &mut b, &mut rr));
    });
    println!("{r}");

    // ---- prox throughput
    let mut rng = Pcg::seeded(1);
    let mut blocks: Vec<Vec<f64>> = (0..500).map(|_| rng.normal_vec(10)).collect();
    let r = bench("sgl_prox x500 groups of 10", cfg, |_| {
        for b in blocks.iter_mut() {
            sgl_prox_inplace(b, 0.1, 0.2);
        }
        black_box(&blocks);
    });
    println!("{r}");

    // ---- matvec kernels
    let v = rng.normal_vec(pb.p());
    let mut out_n = vec![0.0; pb.n()];
    let r = bench("X*v (dense matvec)", cfg, |_| {
        pb.x.matvec_into(black_box(&v), &mut out_n);
        black_box(&out_n);
    });
    println!("{r}");
    let u = rng.normal_vec(pb.n());
    let mut out_p = vec![0.0; pb.p()];
    let r = bench("X^T*u (correlation)", cfg, |_| {
        pb.x.tmatvec_into(black_box(&u), &mut out_p);
        black_box(&out_p);
    });
    println!("{r}");
}
