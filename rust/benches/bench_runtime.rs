//! Bench: the PJRT runtime path — per-call latency of the two AOT
//! artifacts and an end-to-end XLA-engine solve vs the native solver on
//! the same problem. Skips (with a message) if `make artifacts` has not
//! been run.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::runtime::engine::XlaEngine;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::problem::SglProblem;
use sgl::util::timer::{bench, black_box, BenchConfig, Stopwatch};
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.toml").exists() {
        println!("bench_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    println!("== bench_runtime: PJRT artifact execution ==\n");
    let engine = XlaEngine::load(&dir).expect("load artifacts");
    let meta = engine.meta.clone();
    println!(
        "artifact shape: n={} p={} ({} groups x {}), {} inner steps/call",
        meta.n, meta.p, meta.n_groups, meta.group_size, meta.n_inner
    );

    let cfg = SyntheticConfig {
        n: meta.n,
        n_groups: meta.n_groups,
        group_size: meta.group_size,
        gamma1: 5.min(meta.n_groups),
        gamma2: 4.min(meta.group_size),
        seed: 42,
        ..Default::default()
    };
    let d = generate(&cfg);
    let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.2);
    let session = engine.session(&pb).expect("session");
    let lambda = 0.2 * pb.lambda_max();
    let bcfg = BenchConfig { warmup_iters: 2, iters: 15, max_seconds: 30.0 };

    // Single-round latency: 1 screen + 1 epoch call (max_rounds=1 forces
    // exactly one of each without convergence).
    let r = bench("xla 1 round (screen + epoch call)", bcfg, |_| {
        black_box(session.solve(lambda, 0.0, 1, None, true).unwrap());
    });
    println!("{r}");

    // Full solve latency, screening on/off.
    for (name, screening) in
        [("xla solve 1e-8 (screen on)", true), ("xla solve 1e-8 (screen off)", false)]
    {
        let r = bench(name, bcfg, |_| {
            black_box(session.solve(lambda, 1e-8, 5000, None, screening).unwrap());
        });
        println!("{r}");
    }

    // Native comparison on the identical problem.
    let r = bench("native cd solve 1e-8 (gap_safe)", bcfg, |_| {
        black_box(solve(
            &pb,
            lambda,
            None,
            &SolveOptions {
                rule: RuleKind::GapSafe,
                tol: 1e-8,
                record_history: false,
                ..Default::default()
            },
        ));
    });
    println!("{r}");

    // Warm-started path through the engine (the e2e serving pattern).
    let sw = Stopwatch::start();
    let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 2.0, 10);
    let mut warm: Option<Vec<f64>> = None;
    for &l in &lambdas {
        let res = session.solve(l, 1e-8, 5000, warm.as_deref(), true).unwrap();
        warm = Some(res.beta);
    }
    println!(
        "xla warm path (10 lambdas to 1e-8):             {:>12.1} ms total",
        sw.elapsed_ms()
    );
}
