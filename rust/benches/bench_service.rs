//! Bench: the L4 solve service — job throughput, duplicate-traffic cache
//! hits, and λ-sharded vs monolithic single-path latency.
//!
//! Sections:
//!
//! 1. **Throughput** — a heterogeneous batch (every screening rule × three
//!    tolerances) of CSC path jobs on a ~1%-density design, submitted at
//!    once and drained through the completion stream; reports jobs/sec
//!    plus the queue-wait and per-job latency histograms the service's
//!    metrics timers record.
//! 2. **Duplicate traffic** — the same batch resubmitted; every job must
//!    be answered from the fingerprint cache without re-solving.
//! 3. **Sharding** — one long path on a ≥ 5000-feature problem solved
//!    monolithically vs as k=4 pipelined λ-shards with dual-point
//!    handoff, both directly and through the service; asserts final
//!    objectives agree to ≤ 1e-8 at every λ and reports the latency
//!    comparison (the shard boundaries should cost ~nothing — that is
//!    the property that lets one huge path spread across machines).
//!
//! 4. **Fleet scheduling** — a ≥ 2-path sharded batch on an in-process
//!    2-worker TCP fleet, scheduled two ways: *serialized* (one path at a
//!    time, its shards in sequence — the fleet idles at 1 busy worker)
//!    vs *cross-path interleaved* (`solve_batch_interleaved`: different
//!    paths' shards overlap, only the intra-path handoff dependency
//!    serializes). Asserts the interleaved schedule is faster on ≥ 2
//!    cores and that both produce bit-identical results.
//!
//! 5. **Churn** — the same interleaved batch run calm and then under a
//!    scripted worker kill + registered replacement mid-run: zero lost
//!    jobs, bit-identical results either way, and the recovery cost
//!    (wall-clock overhead, requeues, rejoins) on record.
//!
//! Default scale runs in seconds; `SGL_BENCH_SCALE=paper` runs the full
//! p=10000 instances.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet, WorkerServer};
use sgl::coordinator::service::{
    AnyProblem, ServiceConfig, SolveRequest, SolveService,
};
use sgl::coordinator::shard::{solve_batch_interleaved, solve_path_sharded, InterleavedJob};
use sgl::solver::path::DualHandoff;
use sgl::data::sparse::{self, SparseSyntheticConfig};
use sgl::linalg::{CscMatrix, Design};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::path::{solve_path_on_grid, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use sgl::util::json::Json;
use sgl::util::timer::Stopwatch;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

fn unit_norm_problem(cfg: &SparseSyntheticConfig, tau: f64) -> Arc<SglProblem<CscMatrix>> {
    let d = sparse::generate(cfg);
    let y_norm = d.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.y.iter().map(|v| v / y_norm).collect();
    Arc::new(SglProblem::new(d.x, y, d.groups, tau))
}

fn main() {
    let paper = std::env::var("SGL_BENCH_SCALE").as_deref() == Ok("paper");
    let throughput = throughput_and_cache(paper);
    let sharding = sharded_vs_monolithic(paper);
    let fleet = fleet_interleaved_vs_serialized(paper);
    let churn = churn_recovery(paper);
    // Machine-readable summary next to the printed report, for tracking
    // bench results across commits.
    let out = Json::obj()
        .with("kernels", sgl::linalg::simd::effective().name())
        .with("scale", if paper { "paper" } else { "small" })
        .with("throughput", throughput)
        .with("sharding", sharding)
        .with("fleet", fleet)
        .with("churn", churn);
    std::fs::write("BENCH_service.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_service.json");
}

fn throughput_and_cache(paper: bool) -> Json {
    let cfg = SparseSyntheticConfig {
        n: 100,
        n_groups: if paper { 1000 } else { 300 },
        group_size: 10,
        density: 0.01,
        gamma1: 10,
        gamma2: 4,
        seed: 42,
        ..Default::default()
    };
    let pb = unit_norm_problem(&cfg, 0.2);
    let t_count = if paper { 60 } else { 25 };
    let svc = SolveService::start(ServiceConfig::default());
    println!(
        "== bench_service: n={}, p={}, nnz={}, T={t_count}, {} workers ==\n",
        pb.n(),
        pb.p(),
        pb.x.nnz(),
        svc.workers()
    );

    let make_batch = || -> Vec<SolveRequest> {
        let mut batch = Vec::new();
        for rule in RuleKind::all() {
            for tol in [1e-4, 1e-6, 1e-8] {
                batch.push(SolveRequest {
                    label: format!("{}@{tol:.0e}", rule.name()),
                    ..SolveRequest::new(
                        AnyProblem::Csc(pb.clone()),
                        PathOptions {
                            delta: 2.0,
                            t_count,
                            solve: SolveOptions {
                                tol,
                                rule,
                                record_history: false,
                                ..Default::default()
                            },
                        },
                    )
                });
            }
        }
        batch
    };

    // -- throughput: submit everything, drain the completion stream.
    let batch = make_batch();
    let n_jobs = batch.len();
    let sw = Stopwatch::start();
    let ids: Vec<_> = batch.into_iter().map(|r| svc.submit(r).unwrap()).collect();
    let mut completed = 0;
    while svc.wait_next().is_some() {
        completed += 1;
    }
    let secs = sw.elapsed_s();
    assert_eq!(completed, n_jobs);
    for id in &ids {
        assert!(svc.result(*id).expect("done").all_converged());
    }
    println!(
        "throughput: {n_jobs} heterogeneous path jobs in {secs:.3}s = {:.2} jobs/s",
        n_jobs as f64 / secs.max(1e-12)
    );
    let m = svc.metrics();
    let wait = m.timer("service_queue_wait_s").unwrap();
    let lat = m.timer("service_job_latency_s").unwrap();
    println!(
        "queue wait  (s): min {:.4} / mean {:.4} / max {:.4}",
        wait.min,
        wait.mean(),
        wait.max
    );
    println!(
        "job latency (s): min {:.4} / mean {:.4} / max {:.4}",
        lat.min,
        lat.mean(),
        lat.max
    );

    // -- duplicate traffic: all answered from the fingerprint cache.
    let sw = Stopwatch::start();
    let dup_ids: Vec<_> =
        make_batch().into_iter().map(|r| svc.submit(r).unwrap()).collect();
    while svc.wait_next().is_some() {}
    let dup_secs = sw.elapsed_s();
    assert!(dup_ids.iter().all(|&id| svc.was_cached(id)), "all duplicates cached");
    assert_eq!(m.counter("service_cache_hits"), n_jobs as u64);
    println!(
        "\nduplicate traffic: {n_jobs} cache hits in {dup_secs:.4}s \
         (vs {secs:.3}s solved, {:.0}x)",
        secs / dup_secs.max(1e-12)
    );
    Json::obj()
        .with("jobs", n_jobs)
        .with("workers", svc.workers())
        .with("solve_s", secs)
        .with("duplicate_s", dup_secs)
        .with("queue_wait_mean_s", wait.mean())
        .with("job_latency_mean_s", lat.mean())
        .with("cache_hits", m.counter("service_cache_hits") as i64)
}

fn sharded_vs_monolithic(paper: bool) -> Json {
    let cfg = SparseSyntheticConfig {
        n: 100,
        n_groups: if paper { 1000 } else { 550 },
        group_size: 10,
        density: 0.01,
        gamma1: 10,
        gamma2: 4,
        seed: 7,
        ..Default::default()
    };
    let pb = unit_norm_problem(&cfg, 0.2);
    assert!(pb.p() >= 5000, "shard bench must run at >= 5000 features");
    let t_count = if paper { 60 } else { 40 };
    let lambdas = lambda_grid(pb.lambda_max(), 2.0, t_count);
    let opts = PathOptions {
        delta: 2.0,
        t_count,
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 1e-8,
            record_history: false,
            ..Default::default()
        },
    };
    println!(
        "\n== sharded vs monolithic: n={}, p={}, T={t_count}, gap_safe_seq @1e-8 ==",
        pb.n(),
        pb.p()
    );

    let sw = Stopwatch::start();
    let mono = solve_path_on_grid(pb.as_ref(), &lambdas, &opts);
    let t_mono = sw.elapsed_s();
    let sw = Stopwatch::start();
    let sharded = solve_path_sharded(pb.as_ref(), &lambdas, &opts, SolverKind::Cd, 4);
    let t_shard = sw.elapsed_s();
    assert!(mono.all_converged() && sharded.all_converged());

    let objective = |lambda: f64, beta: &[f64]| {
        let xb = pb.x.matvec(beta);
        let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
        0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
    };
    let mut max_div = 0.0_f64;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let a = objective(lambda, &mono.results[i].beta);
        let b = objective(lambda, &sharded.results[i].beta);
        max_div = max_div.max((a - b).abs());
    }
    println!("monolithic path:        {t_mono:>8.3}s");
    println!(
        "sharded path (k=4):     {t_shard:>8.3}s  (boundary overhead {:+.1}%)",
        100.0 * (t_shard - t_mono) / t_mono.max(1e-12)
    );
    println!("max objective divergence: {max_div:.2e}");
    assert!(max_div <= 1e-8, "sharded diverged beyond budget: {max_div:.2e}");

    // End-to-end through the service: the k=4 pipeline as queued jobs.
    let svc = SolveService::start(ServiceConfig::default());
    let req = SolveRequest {
        shards: 4,
        label: "sharded-k4".into(),
        ..SolveRequest::new(AnyProblem::Csc(pb.clone()), opts.clone())
    };
    let sw = Stopwatch::start();
    let id = svc.submit(req).unwrap();
    let via_service = svc.wait(id).unwrap();
    let t_svc = sw.elapsed_s();
    for (a, b) in mono.results.iter().zip(&via_service.results) {
        assert_eq!(a.beta, b.beta, "service pipeline must match monolithic");
    }
    println!("sharded via service:    {t_svc:>8.3}s  (end-to-end, incl. queue)");
    Json::obj()
        .with("p", pb.p())
        .with("monolithic_s", t_mono)
        .with("sharded_s", t_shard)
        .with("via_service_s", t_svc)
        .with("max_objective_divergence", max_div)
}

/// Cross-path interleaving on a loopback 2-worker fleet: a batch of
/// k-sharded paths must beat the serialized-fleet schedule (one path's
/// shards at a time), because the ready-queue scheduler keeps every
/// worker busy with *other* paths' shards while a path waits on its own
/// handoff chain.
fn fleet_interleaved_vs_serialized(paper: bool) -> Json {
    let cfg = SparseSyntheticConfig {
        n: 100,
        n_groups: if paper { 1000 } else { 250 },
        group_size: 10,
        density: 0.01,
        gamma1: 10,
        gamma2: 4,
        seed: 11,
        ..Default::default()
    };
    let pb = unit_norm_problem(&cfg, 0.2);
    let t_count = if paper { 48 } else { 24 };
    let shards = 4;

    let metrics = Arc::new(Metrics::new());
    let servers: Vec<WorkerServer> =
        (0..2).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), metrics.clone())
        .expect("connect fleet");
    println!(
        "\n== fleet scheduling: {} workers, {} paths x k={shards} shards, p={}, T={t_count} ==",
        fleet.capacity(),
        3,
        pb.p()
    );

    let jobs: Vec<InterleavedJob> = [1e-5, 1e-6, 1e-7]
        .iter()
        .map(|&tol| InterleavedJob {
            pb: AnyProblem::Csc(pb.clone()),
            lambdas: lambda_grid(pb.lambda_max(), 2.0, t_count),
            opts: PathOptions {
                delta: 2.0,
                t_count,
                solve: SolveOptions {
                    rule: RuleKind::GapSafeSeq,
                    tol,
                    record_history: false,
                    ..Default::default()
                },
            },
            solver: SolverKind::Cd,
            shards,
            label: format!("path@{tol:.0e}"),
        })
        .collect();
    let exec = |job: &InterleavedJob, grid: &[f64], h: Option<&DualHandoff>| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    };

    // Warm every worker's dataset store deterministically so neither
    // timed schedule pays the one-time ship.
    let warmed = fleet.warm(&AnyProblem::Csc(pb.clone())).expect("warm the fleet");
    assert_eq!(warmed, 2, "both workers must be pre-shipped");

    // -- serialized-fleet schedule: paths one after another, shards in
    // sequence; at most one worker is ever busy.
    let sw = Stopwatch::start();
    let mut serialized = Vec::new();
    for job in &jobs {
        let plan = sgl::coordinator::shard::plan_shards(job.lambdas.len(), job.shards);
        let mut carried: Option<DualHandoff> = None;
        let mut parts = Vec::new();
        for (a, b) in plan {
            let (part, h) = exec(job, &job.lambdas[a..b], carried.as_ref()).expect("shard");
            carried = h;
            parts.push(part);
        }
        serialized.push(sgl::coordinator::shard::stitch(parts));
    }
    let t_serial = sw.elapsed_s();

    // -- cross-path interleaved schedule over the same fleet.
    let sw = Stopwatch::start();
    let interleaved = solve_batch_interleaved(&jobs, fleet.capacity(), exec);
    let t_inter = sw.elapsed_s();

    for ((job, ser), inter) in jobs.iter().zip(&serialized).zip(&interleaved) {
        let inter = inter.as_ref().expect("interleaved job succeeds");
        for (a, b) in ser.results.iter().zip(&inter.results) {
            assert_eq!(a.beta, b.beta, "{}: schedules must not change results", job.label);
        }
    }
    println!("serialized fleet schedule:   {t_serial:>8.3}s  (1 worker busy at a time)");
    println!(
        "interleaved fleet schedule:  {t_inter:>8.3}s  ({:.2}x)",
        t_serial / t_inter.max(1e-12)
    );
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if cores >= 2 {
        assert!(
            t_inter < t_serial,
            "cross-path interleaving must beat the serialized schedule \
             ({t_inter:.3}s vs {t_serial:.3}s on {cores} cores)"
        );
    } else {
        println!("(single core: skipping the wall-clock assertion)");
    }
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 0);
    Json::obj()
        .with("workers", 2usize)
        .with("paths", jobs.len())
        .with("shards", shards)
        .with("serialized_s", t_serial)
        .with("interleaved_s", t_inter)
}

/// Self-healing under churn: run the same interleaved sharded batch on
/// a calm 2-worker fleet and again while one worker is killed mid-run
/// and a replacement rejoins through the registration listener. Both
/// runs must complete every job with bit-identical results; the report
/// prices the recovery (requeues + re-ship on the rejoined worker).
fn churn_recovery(paper: bool) -> Json {
    let cfg = SparseSyntheticConfig {
        n: 100,
        n_groups: if paper { 1000 } else { 250 },
        group_size: 10,
        density: 0.01,
        gamma1: 10,
        gamma2: 4,
        seed: 13,
        ..Default::default()
    };
    let pb = unit_norm_problem(&cfg, 0.2);
    let t_count = if paper { 48 } else { 24 };
    let shards = 4;
    let jobs: Vec<InterleavedJob> = [1e-6, 1e-7]
        .iter()
        .map(|&tol| InterleavedJob {
            pb: AnyProblem::Csc(pb.clone()),
            lambdas: lambda_grid(pb.lambda_max(), 2.0, t_count),
            opts: PathOptions {
                delta: 2.0,
                t_count,
                solve: SolveOptions {
                    rule: RuleKind::GapSafeSeq,
                    tol,
                    record_history: false,
                    ..Default::default()
                },
            },
            solver: SolverKind::Cd,
            shards,
            label: format!("churn@{tol:.0e}"),
        })
        .collect();
    println!(
        "\n== churn recovery: 2 workers, {} paths x k={shards} shards, p={}, T={t_count} ==",
        jobs.len(),
        pb.p()
    );

    let run = |with_churn: bool| {
        let metrics = Arc::new(Metrics::new());
        let servers: Arc<Vec<WorkerServer>> = Arc::new(
            (0..2).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect(),
        );
        let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
        let fleet = Arc::new(
            RemoteFleet::connect(
                &addrs,
                FleetConfig { rejoin_grace: Duration::from_secs(60), ..FleetConfig::default() },
                metrics.clone(),
            )
            .expect("connect fleet"),
        );
        let reg = fleet.serve_registrations("127.0.0.1:0").expect("registration listener");
        let chaos = with_churn.then(|| {
            let servers = servers.clone();
            let metrics = metrics.clone();
            let reg = reg.to_string();
            thread::spawn(move || {
                // Strike once the batch is demonstrably mid-flight, then
                // bring up a replacement that announces itself.
                let deadline = Instant::now() + Duration::from_secs(300);
                while metrics.counter("fleet_shards_solved") < 1 && Instant::now() < deadline {
                    thread::sleep(Duration::from_millis(2));
                }
                servers[0].kill();
                let fresh = WorkerServer::bind("127.0.0.1:0").expect("bind replacement");
                fresh.register(&reg);
                fresh // kept alive until after the batch completes
            })
        });
        let sw = Stopwatch::start();
        let out = solve_batch_interleaved(&jobs, 2, |job, grid, h| {
            fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
        });
        let secs = sw.elapsed_s();
        let _replacement = chaos.map(|t| t.join().expect("churn thread"));
        let results: Vec<_> = jobs
            .iter()
            .zip(out)
            .map(|(job, r)| r.unwrap_or_else(|e| panic!("{} lost to churn: {e:#}", job.label)))
            .collect();
        (secs, results, metrics)
    };

    let (calm_s, calm, _) = run(false);
    let (churn_s, churned, metrics) = run(true);
    for ((job, a), b) in jobs.iter().zip(&calm).zip(&churned) {
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!(ra.beta, rb.beta, "{}: churn must not change results", job.label);
        }
    }
    let requeued = metrics.counter("fleet_shards_requeued");
    let joined = metrics.counter("fleet_workers_joined");
    assert!(metrics.counter("fleet_worker_disconnects") >= 1, "the kill landed mid-batch");
    assert!(joined >= 1, "the replacement registered");
    println!("calm fleet:              {calm_s:>8.3}s");
    println!(
        "under kill + rejoin:     {churn_s:>8.3}s  ({:+.1}% — {requeued} requeued, \
         {joined} rejoined, 0 lost)",
        100.0 * (churn_s - calm_s) / calm_s.max(1e-12)
    );
    Json::obj()
        .with("paths", jobs.len())
        .with("shards", shards)
        .with("calm_s", calm_s)
        .with("churn_s", churn_s)
        .with("requeued", requeued as i64)
        .with("workers_joined", joined as i64)
        .with("lost_jobs", 0usize)
}
