//! Bench: dual-norm evaluation — Algorithm 1 (O(n_I log n_I) with
//! Remark-9 pruning) vs the naive O(d²) scan vs bisection.
//!
//! Regenerates the paper's complexity claim (Prop. 9 / Rmk. 9): the
//! pruned sorted algorithm wins by orders of magnitude at large d, and
//! `n_I` is typically a small fraction of d.
//!
//! Writes `BENCH_dual_norm.json` for the cross-commit perf trajectory.

use sgl::linalg::simd;
use sgl::norms::epsilon::{lambda, lambda_bisect, pruned_count};
use sgl::norms::sgl::epsilon_norm_naive;
use sgl::util::json::Json;
use sgl::util::rng::Pcg;
use sgl::util::timer::{bench, black_box, BenchConfig};

fn main() {
    println!("== bench_dual_norm: Lambda(x, alpha, R) evaluation ==");
    println!("(alpha, R) from eps_g at tau=0.2, w=sqrt(d)\n");
    let cfg = BenchConfig { warmup_iters: 2, iters: 15, max_seconds: 20.0 };
    let mut rows: Vec<Json> = Vec::new();

    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>8} {:>10}",
        "d", "alg1 (us)", "naive (us)", "bisect (us)", "n_I", "speedup"
    );
    for &d in &[10usize, 100, 1_000, 10_000, 100_000] {
        let mut rng = Pcg::seeded(d as u64);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let tau = 0.2;
        let w = (d as f64).sqrt();
        let eps = (1.0 - tau) * w / (tau + (1.0 - tau) * w);
        let (alpha, r) = (1.0 - eps, eps);

        let fast = bench(&format!("alg1 d={d}"), cfg, |_| {
            black_box(lambda(black_box(&x), alpha, r));
        });
        // The naive quadratic scan becomes prohibitive at large d: cap it.
        let naive = if d <= 10_000 {
            Some(bench(&format!("naive d={d}"), cfg, |_| {
                black_box(epsilon_norm_naive(black_box(&x), eps));
            }))
        } else {
            None
        };
        let bisect = bench(&format!("bisect d={d}"), cfg, |_| {
            black_box(lambda_bisect(black_box(&x), alpha, r, 1e-12));
        });
        let n_i = pruned_count(&x, alpha, r);
        let naive_us = naive.as_ref().map(|b| b.times.median * 1e6);
        println!(
            "{:>8} {:>14.2} {:>14} {:>14.2} {:>8} {:>9.1}x",
            d,
            fast.times.median * 1e6,
            naive_us.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            bisect.times.median * 1e6,
            n_i,
            naive_us.unwrap_or(bisect.times.median * 1e6) / (fast.times.median * 1e6)
        );
        rows.push(
            Json::obj()
                .with("d", d as f64)
                .with("alg1_median_s", fast.times.median)
                .with(
                    "naive_median_s",
                    naive.as_ref().map(|b| Json::Num(b.times.median)).unwrap_or(Json::Null),
                )
                .with("bisect_median_s", bisect.times.median)
                .with("n_i", n_i as f64),
        );
    }

    // Adversarial case: near-uniform magnitudes defeat pruning (n_I ~ d).
    println!("\nadversarial (all-equal coordinates, pruning inert):");
    let mut adversarial: Vec<Json> = Vec::new();
    for &d in &[1_000usize, 100_000] {
        let x: Vec<f64> = vec![1.0; d];
        let eps = 0.9;
        let (alpha, r) = (1.0 - eps, eps);
        let fast = bench(&format!("alg1 flat d={d}"), cfg, |_| {
            black_box(lambda(black_box(&x), alpha, r));
        });
        let n_i = pruned_count(&x, alpha, r);
        println!("  d={d:>7}: {:>10.2} us/eval, n_I={}", fast.times.median * 1e6, n_i);
        adversarial.push(
            Json::obj()
                .with("d", d as f64)
                .with("alg1_median_s", fast.times.median)
                .with("n_i", n_i as f64),
        );
    }

    let out = Json::obj()
        .with("bench", "dual_norm")
        .with("kernels", simd::effective().name())
        .with("rows", Json::Arr(rows))
        .with("adversarial", Json::Arr(adversarial));
    std::fs::write("BENCH_dual_norm.json", out.pretty()).expect("write bench json");
    println!("\nwrote BENCH_dual_norm.json");
}
